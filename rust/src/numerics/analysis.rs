//! Imprecision diagnostics: the lost-arithmetic predicate (Def. 3.2), the
//! imprecision percentage (Fig. 3 left) and the paper's novel metric,
//! **effective descent quality** (EDQ, Def. 3.3).

use super::format::FloatFormat;

/// Fixed block length for all f64 diagnostics reductions (and the chunk
/// length of the fused optimizer kernels, `optim::kernels::CHUNK`).
///
/// Every Σ here accumulates sequentially *within* `ACCUM_CHUNK`-element
/// blocks and combines the block partials in index order.  The block grid
/// depends only on `n`, so the fused kernels — which produce exactly these
/// partials, one per chunk, on any number of threads — reduce to
/// bit-identical totals.  ~16K elements also keeps a block's working set
/// inside L2, which is why the same constant serves as the kernel tile.
pub const ACCUM_CHUNK: usize = 1 << 14;

/// Σ xᵢ² over f64 values, reduced on the [`ACCUM_CHUNK`] grid (the
/// parameter-norm reduction of the reference optimizer path).
pub fn sum_sq_chunked(xs: &[f64]) -> f64 {
    let mut total = 0.0f64;
    for block in xs.chunks(ACCUM_CHUNK) {
        let mut acc = 0.0f64;
        for &x in block {
            acc += x * x;
        }
        total += acc;
    }
    total
}

/// Def. 3.2: the operation `F(a ∘ b) = r` is *lost* if the result collapsed
/// onto one of its operands, i.e. `|r - a| <= ulp(a)/2` (so `r == a`) or
/// symmetric in b.
pub fn is_lost(fmt: &FloatFormat, a: f32, b: f32, result: f32) -> bool {
    ((result - a).abs() as f64) <= fmt.ulp(a) / 2.0
        || ((result - b).abs() as f64) <= fmt.ulp(b) / 2.0
}

/// The common LLM-training special case (Sec. 3.2): an update addition
/// `θ ⊕ Δθ` is lost when the parameter did not move despite a non-zero
/// intended update.
pub fn update_lost(theta_old: f32, theta_new: f32, dtheta: f32) -> bool {
    dtheta != 0.0 && theta_new == theta_old
}

/// Fraction of parameters whose update was lost (Fig. 3 left: "imprecision
/// percentage").
pub fn lost_fraction(theta_old: &[f32], theta_new: &[f32], dtheta: &[f32]) -> f64 {
    assert_eq!(theta_old.len(), theta_new.len());
    assert_eq!(theta_old.len(), dtheta.len());
    if theta_old.is_empty() {
        return 0.0;
    }
    let lost = theta_old
        .iter()
        .zip(theta_new)
        .zip(dtheta)
        .filter(|((&o, &n), &d)| update_lost(o, n, d))
        .count();
    lost as f64 / theta_old.len() as f64
}

/// Full EDQ report for one optimizer step.
#[derive(Debug, Clone, Copy, Default)]
pub struct EdqReport {
    /// ‖Δθ‖₂ — intended update norm.
    pub update_norm: f64,
    /// ‖Δθ̂‖₂ — effective update norm (Eq. 2).
    pub effective_norm: f64,
    /// ⟨Δθ/‖Δθ‖, Δθ̂⟩ (Eq. 3).  Equals `update_norm` when nothing is lost.
    pub edq: f64,
    /// EDQ normalized by the intended norm ∈ [~0, 1]: 1 = no loss.
    pub edq_ratio: f64,
}

/// EDQ (Def. 3.3) of an effective update `theta_new - theta_old` versus the
/// intended update `dtheta`.  For MCF strategies pass the *evaluated*
/// parameters (hi + lo).
pub fn edq(theta_old: &[f32], theta_new: &[f32], dtheta: &[f32]) -> EdqReport {
    assert_eq!(theta_old.len(), theta_new.len());
    assert_eq!(theta_old.len(), dtheta.len());
    // Chunked reduction on the ACCUM_CHUNK grid — see the constant's docs.
    let mut un2 = 0.0f64;
    let mut en2 = 0.0f64;
    let mut dot = 0.0f64;
    for ((old_b, new_b), d_b) in theta_old
        .chunks(ACCUM_CHUNK)
        .zip(theta_new.chunks(ACCUM_CHUNK))
        .zip(dtheta.chunks(ACCUM_CHUNK))
    {
        let mut p_un2 = 0.0f64;
        let mut p_en2 = 0.0f64;
        let mut p_dot = 0.0f64;
        for ((&o, &n), &d) in old_b.iter().zip(new_b).zip(d_b) {
            let eff = n as f64 - o as f64;
            p_un2 += (d as f64) * (d as f64);
            p_en2 += eff * eff;
            p_dot += (d as f64) * eff;
        }
        un2 += p_un2;
        en2 += p_en2;
        dot += p_dot;
    }
    let update_norm = un2.sqrt();
    let effective_norm = en2.sqrt();
    let edq = if update_norm > 0.0 { dot / update_norm } else { 0.0 };
    EdqReport {
        update_norm,
        effective_norm,
        edq,
        edq_ratio: if update_norm > 0.0 { edq / update_norm } else { 1.0 },
    }
}

/// EDQ with expansion-valued parameters (hi/lo pairs evaluated in f64).
pub fn edq_expansion(
    theta_old_hi: &[f32],
    theta_old_lo: &[f32],
    theta_new_hi: &[f32],
    theta_new_lo: &[f32],
    dtheta: &[f32],
) -> EdqReport {
    let n = dtheta.len();
    // Same ACCUM_CHUNK-grid reduction as `edq`, over expansion values.
    let mut un2 = 0.0f64;
    let mut en2 = 0.0f64;
    let mut dot = 0.0f64;
    for start in (0..n).step_by(ACCUM_CHUNK) {
        let end = (start + ACCUM_CHUNK).min(n);
        let mut p_un2 = 0.0f64;
        let mut p_en2 = 0.0f64;
        let mut p_dot = 0.0f64;
        for i in start..end {
            let old = theta_old_hi[i] as f64 + theta_old_lo[i] as f64;
            let new = theta_new_hi[i] as f64 + theta_new_lo[i] as f64;
            let eff = new - old;
            let d = dtheta[i] as f64;
            p_un2 += d * d;
            p_en2 += eff * eff;
            p_dot += d * eff;
        }
        un2 += p_un2;
        en2 += p_en2;
        dot += p_dot;
    }
    let update_norm = un2.sqrt();
    EdqReport {
        update_norm,
        effective_norm: en2.sqrt(),
        edq: if update_norm > 0.0 { dot / update_norm } else { 0.0 },
        edq_ratio: if update_norm > 0.0 { dot / (update_norm * update_norm) } else { 1.0 },
    }
}

/// EDQ over pre-evaluated effective parameters (f64) — the MCF reducer for
/// expansion plans of *any* component count (length-2 pairs, length-3
/// expansions, loss-scaled δθ words alike): callers evaluate
/// `θ_eff = hi + 2⁻ᵏ·Σδθᵢ` per element and this reduces exactly like
/// [`edq_expansion`] (same `ACCUM_CHUNK` grid, same `dot/‖Δθ‖²` ratio), so
/// for hi/lo pairs the two are bitwise interchangeable.
pub fn edq_effective(old_eff: &[f64], new_eff: &[f64], dtheta: &[f32]) -> EdqReport {
    let n = dtheta.len();
    assert_eq!(old_eff.len(), n);
    assert_eq!(new_eff.len(), n);
    let mut un2 = 0.0f64;
    let mut en2 = 0.0f64;
    let mut dot = 0.0f64;
    for start in (0..n).step_by(ACCUM_CHUNK) {
        let end = (start + ACCUM_CHUNK).min(n);
        let mut p_un2 = 0.0f64;
        let mut p_en2 = 0.0f64;
        let mut p_dot = 0.0f64;
        for i in start..end {
            let eff = new_eff[i] - old_eff[i];
            let d = dtheta[i] as f64;
            p_un2 += d * d;
            p_en2 += eff * eff;
            p_dot += d * eff;
        }
        un2 += p_un2;
        en2 += p_en2;
        dot += p_dot;
    }
    let update_norm = un2.sqrt();
    EdqReport {
        update_norm,
        effective_norm: en2.sqrt(),
        edq: if update_norm > 0.0 { dot / update_norm } else { 0.0 },
        edq_ratio: if update_norm > 0.0 { dot / (update_norm * update_norm) } else { 1.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::expansion::rn_bf16;
    use crate::numerics::format::BF16;

    #[test]
    fn lost_arithmetic_paper_example() {
        // F(200 ⊕ 0.1) = 200 (Sec. 3.1 remark).
        let r = rn_bf16(200.0 + 0.1);
        assert_eq!(r, 200.0);
        assert!(is_lost(&BF16, 200.0, 0.1, r));
        // A balanced add is not lost.
        let r2 = rn_bf16(1.0 + 1.0);
        assert!(!is_lost(&BF16, 1.0, 1.0, r2));
    }

    #[test]
    fn edq_no_loss_equals_norm() {
        // When the effective update IS the intended update, EDQ = ‖Δθ‖.
        let old = [1.0f32, 2.0, -3.0];
        let d = [0.5f32, -0.25, 0.125];
        let new: Vec<f32> = old.iter().zip(&d).map(|(o, x)| o + x).collect();
        let r = edq(&old, &new, &d);
        assert!((r.edq - r.update_norm).abs() < 1e-9);
        assert!((r.edq_ratio - 1.0).abs() < 1e-9);
    }

    #[test]
    fn edq_total_loss_is_zero() {
        let old = [200.0f32; 4];
        let d = [0.1f32; 4];
        let new = old; // nothing moved
        let r = edq(&old, &new, &d);
        assert_eq!(r.edq, 0.0);
        assert_eq!(r.effective_norm, 0.0);
        assert_eq!(lost_fraction(&old, &new, &d), 1.0);
    }

    #[test]
    fn edq_partial_loss_between() {
        let old = [200.0f32, 1.0];
        let d = [0.1f32, 0.1];
        let new = [200.0f32, 1.1]; // first lost, second applied
        let r = edq(&old, &new, &d);
        assert!(r.edq > 0.0 && r.edq < r.update_norm);
        assert!((lost_fraction(&old, &new, &d) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn edq_effective_bitwise_matches_edq_expansion_on_pairs() {
        // The generalized reducer must be a drop-in for the hi/lo one.
        let old_hi = [200.0f32, 1.0, -3.5, 0.25];
        let old_lo = [0.0f32, 0.001953125, 0.0078125, 0.0];
        let new_hi = [200.0f32, 1.0078125, -3.5, 0.25];
        let new_lo = [0.099609375f32, 0.0, 0.0078125, -0.001953125];
        let d = [0.1f32, 0.01, 0.0, -0.002];
        let r1 = edq_expansion(&old_hi, &old_lo, &new_hi, &new_lo, &d);
        let old_eff: Vec<f64> =
            old_hi.iter().zip(&old_lo).map(|(&h, &l)| h as f64 + l as f64).collect();
        let new_eff: Vec<f64> =
            new_hi.iter().zip(&new_lo).map(|(&h, &l)| h as f64 + l as f64).collect();
        let r2 = edq_effective(&old_eff, &new_eff, &d);
        assert_eq!(r1.update_norm.to_bits(), r2.update_norm.to_bits());
        assert_eq!(r1.effective_norm.to_bits(), r2.effective_norm.to_bits());
        assert_eq!(r1.edq.to_bits(), r2.edq.to_bits());
        assert_eq!(r1.edq_ratio.to_bits(), r2.edq_ratio.to_bits());
    }

    #[test]
    fn expansion_edq_sees_lo_component() {
        // The hi components don't move but lo accumulates: EDQ(MCF) > 0
        // while EDQ(hi only) = 0 — why Collage tracks near-optimal EDQ.
        let old_hi = [200.0f32];
        let old_lo = [0.0f32];
        let d = [0.1f32];
        let new_hi = [200.0f32];
        let new_lo = [rn_bf16(0.1)];
        let r = edq_expansion(&old_hi, &old_lo, &new_hi, &new_lo, &d);
        assert!(r.edq > 0.09, "edq={}", r.edq);
        let r_hi = edq(&old_hi, &new_hi, &d);
        assert_eq!(r_hi.edq, 0.0);
    }
}
