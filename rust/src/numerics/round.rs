//! Rounding modes beyond RN-even: stochastic rounding (paper Appendix B)
//! and directed rounding helpers used by tests.
//!
//! Everything here derives from the RN-even contract documented in
//! [`super::format`]: the directed modes bracket a value between the two
//! adjacent grid points by re-rounding nudged inputs (correct across
//! binade boundaries, where the grid spacing halves), and stochastic
//! rounding picks between that same bracket with probability proportional
//! to the position inside it.  All of them therefore ride the bit-parallel
//! fast paths of [`FloatFormat::round_nearest_f64`] — no extra per-element
//! `log2`/`powi` — and inherit its subnormal/overflow/NaN semantics.
//!
//! The optimizer kernels use the counter-based variant
//! (`optim::kernels::sr_round_fmt`) so the draw is a pure function of
//! `(step key, element index)`; the [`stochastic_round`] here draws from a
//! caller-provided [`Rng`] stream and is the simpler reference form.
//!
//! ```
//! use collage::numerics::format::FP8E4M3;
//! use collage::numerics::round::{round_down, round_up};
//! // 17 sits between the e4m3 grid points 16 and 18 (ulp(16) = 2).
//! assert_eq!(round_down(&FP8E4M3, 17.0), 16.0);
//! assert_eq!(round_up(&FP8E4M3, 17.0), 18.0);
//! // On-grid values are fixed points of both directed modes.
//! assert_eq!(round_down(&FP8E4M3, 18.0), 18.0);
//! assert_eq!(round_up(&FP8E4M3, 18.0), 18.0);
//! ```

use crate::util::rng::Rng;

use super::format::FloatFormat;

/// Round `x` down to the format grid (toward −inf).
pub fn round_down(fmt: &FloatFormat, x: f64) -> f32 {
    let r = fmt.round_nearest_f64(x);
    if (r as f64) <= x {
        r
    } else {
        prev_repr(fmt, r)
    }
}

/// Round `x` up to the format grid (toward +inf).
pub fn round_up(fmt: &FloatFormat, x: f64) -> f32 {
    let r = fmt.round_nearest_f64(x);
    if (r as f64) >= x {
        r
    } else {
        next_repr(fmt, r)
    }
}

fn next_repr(fmt: &FloatFormat, x: f32) -> f32 {
    let u = fmt.ulp(x) as f64;
    fmt.round_nearest_f64(x as f64 + u)
}

fn prev_repr(fmt: &FloatFormat, x: f32) -> f32 {
    // Below a power of two the downward spacing halves; stepping by the
    // half-ulp and re-rounding lands on the previous grid point.
    let u = fmt.ulp(x) as f64;
    let cand = fmt.round_nearest_f64(x as f64 - u / 2.0);
    if cand < x {
        cand
    } else {
        fmt.round_nearest_f64(x as f64 - u)
    }
}

/// Stochastic rounding (App. B): rounds to the lower neighbour `a_l` with
/// probability `(a_u - x)/(a_u - a_l)`, upper neighbour otherwise; unbiased:
/// `E[SR(x)] = x`.
pub fn stochastic_round(fmt: &FloatFormat, x: f64, rng: &mut Rng) -> f32 {
    if x.is_nan() {
        return f32::NAN;
    }
    let lo = round_down(fmt, x);
    let hi = round_up(fmt, x);
    if lo == hi || (lo as f64) == x {
        return lo;
    }
    let p_up = (x - lo as f64) / (hi as f64 - lo as f64);
    if rng.f64() < p_up {
        hi
    } else {
        lo
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::format::BF16;

    #[test]
    fn directed_bracket_the_value() {
        let mut rng = Rng::new(3, 0);
        for _ in 0..5000 {
            let x = rng.normal() * 10f64.powi(rng.below(12) as i32 - 6);
            let lo = round_down(&BF16, x);
            let hi = round_up(&BF16, x);
            assert!((lo as f64) <= x, "lo {lo} > x {x}");
            assert!((hi as f64) >= x, "hi {hi} < x {x}");
            assert!(BF16.representable(lo) && BF16.representable(hi));
        }
    }

    #[test]
    fn exact_values_fixed_points() {
        let mut rng = Rng::new(4, 0);
        for _ in 0..1000 {
            let x = BF16.round_nearest(rng.normal() as f32) as f64;
            assert_eq!(round_down(&BF16, x), x as f32);
            assert_eq!(round_up(&BF16, x), x as f32);
            assert_eq!(stochastic_round(&BF16, x, &mut rng), x as f32);
        }
    }

    #[test]
    fn sr_is_unbiased() {
        // E[SR(x)] = x: average many draws of a value between grid points.
        let mut rng = Rng::new(5, 0);
        let x = 1.0 + 0.3 * BF16.ulp_one(); // 30% of the way to the next grid point
        let n = 50_000;
        let mean: f64 = (0..n)
            .map(|_| stochastic_round(&BF16, x, &mut rng) as f64)
            .sum::<f64>()
            / n as f64;
        let err = (mean - x).abs() / BF16.ulp_one();
        assert!(err < 0.02, "bias {err} ulp");
    }

    #[test]
    fn sr_escapes_lost_arithmetic() {
        // 200 ⊕ 0.1 is lost under RN (Sec. 3.1) but SR moves eventually.
        let mut rng = Rng::new(6, 0);
        let mut x = 200.0f32;
        for _ in 0..1000 {
            x = stochastic_round(&BF16, x as f64 + 0.1, &mut rng);
        }
        assert!(x > 200.0, "SR never rounded up");
    }
}
