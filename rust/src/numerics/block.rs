//! Block-scaled microscaling (MX) quantization: MXFP4 per the OCP
//! Microscaling spec — 32 E2M1 elements sharing one E8M0 power-of-two
//! scale ("Training LLMs with MXFP4"; "Exploring FP4 Precision").
//!
//! # The block contract
//!
//! A **block** is up to [`BLOCK`] = 32 consecutive elements on the global
//! index grid (blocks never straddle `ACCUM_CHUNK` boundaries —
//! `ACCUM_CHUNK % BLOCK == 0` — so chunk-sharded kernels see the same
//! blocks at any worker count; the last block of a vector may be short).
//! Quantizing a block:
//!
//! 1. **Scale selection** (the OCP rule): the shared scale is
//!    `2^e` with `e = floor(log2(max|x|)) − 2` (2 = E2M1's top binade,
//!    6 = 1.5·2²), clamped to `e ∈ [`[`SCALE_E_MIN`]`, `[`SCALE_E_MAX`]`]`.
//!    The block max therefore lands in `[4·2^e, 8·2^e)`.
//! 2. **Element rounding**: each element rounds to nearest on the E2M1
//!    magnitude grid `{0, 0.5, 1, 1.5, 2, 3, 4, 6}·2^e`, **ties to the
//!    even mantissa code** (magnitudes 0, 1, 2, 4), values beyond
//!    `6·2^e` clamping to `±6·2^e` (only the block max can be there, and
//!    E2M1 has no infinities).  The sign of zero is preserved.
//!
//! Pinned edge behavior (property-tested in `tests/block_format.rs`):
//!
//! * **All-zero block** → scale exponent 0, all elements ±0.
//! * **Any non-finite element** → the whole block quantizes to NaN and
//!   the scale is reported as `None` (E8M0's NaN scale code).
//! * The scale depends on the block only through `max|x|`: it is
//!   invariant under element permutation and monotone in the max.
//!
//! # The element-wise view
//!
//! The scale-exponent clamp is chosen so that the union of every block
//! grid is **exactly** the element grid of
//! [`MXFP4`](crate::numerics::format::MXFP4) = `FloatFormat { exp_bits:
//! 8, mantissa_bits: 1 }` (every decodable value has ≤ 2 significant
//! bits): `0.5·2^SCALE_E_MIN = 2⁻¹²⁷` is that format's smallest
//! subnormal and `6·2^SCALE_E_MAX = 1.5·2¹²⁷` its `max_finite`.  So the
//! repo's element-wise machinery — `representable`, `check_representable`,
//! `ulp`, `default_eps` — describes the decodable set with no changes,
//! while this module owns the *joint* constraint (one shared scale per
//! block).  Block quantization is idempotent, which also means the scale
//! needs no side-channel persistence: the quantized block's own max
//! (always `4·2^e` or `6·2^e`) re-derives `e`, so checkpoints keep
//! storing plain f32 containers.
//!
//! Two implementations provide the contract, mirroring
//! [`format`](crate::numerics::format):
//!
//! * [`quantize_block`] — the fast path: scale exponent read off the f64
//!   exponent bits of the block max, exact power-of-two rescale, and a
//!   branch-chain commit onto the 8-point magnitude grid.  No
//!   `log2`/`floor`/`powi`.
//! * [`quantize_block_reference`] — the executable specification: scale
//!   via `log2().floor()`, then a scan over all 16 code points choosing
//!   the nearest with ties to the even mantissa code.
//!
//! They are bitwise identical for every input; `tests/block_format.rs`
//! sweeps all 16 codes × all block scales × boundary/tie inputs
//! exhaustively in tier 1 (the 4-bit grid is small enough).
//!
//! ```
//! use collage::numerics::block::quantize_block;
//! use collage::numerics::format::MXFP4;
//!
//! let mut x = [0.0f64; 32];
//! x[0] = 1.7;
//! x[1] = -0.02;
//! x[5] = 3.9e-3;
//! let mut q = [0.0f32; 32];
//! let e = quantize_block(&x, &mut q).unwrap();
//! assert_eq!(e, -2); // max |x| = 1.7 → floor(log2 1.7) − 2 = −2
//! // 1.7 · 2² = 6.8 sits past the top code: clamps to 6 · 2⁻² = 1.5.
//! assert_eq!(q[0], 1.5);
//! // -0.02 · 2² = -0.08 rounds to zero, keeping its sign.
//! assert_eq!(q[1], 0.0);
//! assert!(q[1].is_sign_negative());
//! // Every decodable value is on MXFP4's element-wise grid.
//! assert!(q.iter().all(|&v| MXFP4.representable(v)));
//! ```

/// Elements per block (the OCP MX default).
pub const BLOCK: usize = 32;

/// Smallest shared-scale exponent.  E8M0 proper encodes down to −127;
/// clamping one higher keeps the smallest decodable element
/// (`0.5·2^SCALE_E_MIN = 2⁻¹²⁷`) on the element-wise `MXFP4` grid, whose
/// subnormal quantum is `2⁻¹²⁷`.
pub const SCALE_E_MIN: i32 = -126;

/// Largest shared-scale exponent.  E8M0 proper encodes up to +127, but a
/// block max drawn from an f32 container is below 2¹²⁸, so the OCP rule
/// never selects past 125 — and `6·2^SCALE_E_MAX = 1.5·2¹²⁷` is exactly
/// the element-wise `MXFP4.max_finite()`.
pub const SCALE_E_MAX: i32 = 125;

/// The 8 non-negative E2M1 magnitudes, indexed by (exponent, mantissa)
/// code.  Even indices have the even (zero) mantissa bit — the tie
/// winners.  A 4-bit code is `sign << 3 | index`.
pub const E2M1_MAGNITUDES: [f64; 8] = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];

/// `2^q` as an f64 by direct bit construction (normal range only).
#[inline]
fn pow2(q: i32) -> f64 {
    debug_assert!((-1022..=1023).contains(&q), "pow2 exponent {q} out of range");
    f64::from_bits(((q + 1023) as u64) << 52)
}

/// The OCP scale rule on a finite, non-negative block max:
/// `floor(log2(max)) − 2`, clamped; an all-zero block pins to exponent 0.
///
/// Fast path: the floor-log2 is the f64 exponent field.  f64-subnormal
/// maxima (< 2⁻¹⁰²²) are far below the clamp and need no special bit
/// handling.
#[inline]
pub fn select_scale_exp(max_abs: f64) -> i32 {
    debug_assert!(max_abs >= 0.0 && max_abs.is_finite());
    if max_abs == 0.0 {
        return 0;
    }
    let biased = ((max_abs.to_bits() >> 52) & 0x7FF) as i32;
    if biased == 0 {
        return SCALE_E_MIN;
    }
    (biased - 1023 - 2).clamp(SCALE_E_MIN, SCALE_E_MAX)
}

/// Arithmetic twin of [`select_scale_exp`] (`log2().floor()` with the
/// power-of-two fixup), used by the reference quantizer.
fn select_scale_exp_reference(max_abs: f64) -> i32 {
    if max_abs == 0.0 {
        return 0;
    }
    let mut e = max_abs.log2().floor() as i32;
    // log2 misrounds just below powers of two; nudge so 2^e <= max < 2^(e+1).
    if 2f64.powi(e) > max_abs {
        e -= 1;
    }
    if 2f64.powi(e + 1) <= max_abs {
        e += 1;
    }
    (e - 2).clamp(SCALE_E_MIN, SCALE_E_MAX)
}

/// The shared scale exponent a block would select, or `None` if any
/// element is non-finite (the NaN-block case).  Exposed for the
/// block-scale property tests; [`quantize_block`] agrees with it.
pub fn block_scale_exp(x: &[f64]) -> Option<i32> {
    let mut max_abs = 0.0f64;
    for &v in x {
        if !v.is_finite() {
            return None;
        }
        let a = v.abs();
        if a > max_abs {
            max_abs = a;
        }
    }
    Some(select_scale_exp(max_abs))
}

/// RN-even of a non-negative scaled magnitude onto the E2M1 grid
/// `{0, 0.5, 1, 1.5, 2, 3, 4, 6}`, ties to the even mantissa code
/// (0, 1, 2, 4), clamping past 6.  All compares are exact.
#[inline]
fn e2m1_magnitude(m: f64) -> f64 {
    if m <= 0.25 {
        0.0 // tie 0.25 → 0 (even)
    } else if m < 0.75 {
        0.5
    } else if m <= 1.25 {
        1.0 // ties 0.75, 1.25 → 1.0 (even)
    } else if m < 1.75 {
        1.5
    } else if m <= 2.5 {
        2.0 // ties 1.75, 2.5 → 2.0 (even)
    } else if m < 3.5 {
        3.0
    } else if m <= 5.0 {
        4.0 // ties 3.5, 5.0 → 4.0 (even)
    } else {
        6.0 // includes the (6·2^e, 8·2^e) clamp zone
    }
}

/// Round one finite element at a pinned scale exponent: RN-even onto the
/// block grid `{0, ±0.5, …, ±6}·2^e`, clamping past `±6·2^e`, preserving
/// the sign of zero.  Exact: the rescale is a power-of-two multiply and
/// every grid point is f32-representable (down to the subnormal `2⁻¹²⁷`).
#[inline]
pub fn quantize_element(x: f64, scale_exp: i32) -> f32 {
    if !x.is_finite() {
        return f32::NAN;
    }
    let q = e2m1_magnitude((x * pow2(-scale_exp)).abs()) * pow2(scale_exp);
    let v = q as f32;
    if x.is_sign_negative() {
        -v
    } else {
        v
    }
}

/// Quantize one block (≤ [`BLOCK`] elements) into decoded f32 values —
/// the **fast path**.  Returns the shared scale exponent, or `None` when
/// any input is non-finite, in which case the whole block is NaN (the
/// E8M0 NaN scale).  See the module docs for the full contract; bitwise
/// identical to [`quantize_block_reference`].
pub fn quantize_block(x: &[f64], out: &mut [f32]) -> Option<i32> {
    debug_assert!(x.len() <= BLOCK && x.len() == out.len());
    let mut max_abs = 0.0f64;
    let mut finite = true;
    for &v in x {
        finite &= v.is_finite();
        let a = v.abs();
        if a > max_abs {
            max_abs = a;
        }
    }
    if !finite {
        for o in out.iter_mut() {
            *o = f32::NAN;
        }
        return None;
    }
    let e = select_scale_exp(max_abs);
    for (o, &v) in out.iter_mut().zip(x) {
        *o = quantize_element(v, e);
    }
    Some(e)
}

/// The executable specification of block quantization: arithmetic scale
/// selection, then per element a scan over all 16 E2M1 code points
/// choosing the nearest (ties to the even mantissa code).  ~10× the cost
/// of [`quantize_block`]; kept as the oracle for the conformance suite
/// and the `GenericAdamW` reference optimizer.
pub fn quantize_block_reference(x: &[f64], out: &mut [f32]) -> Option<i32> {
    debug_assert!(x.len() <= BLOCK && x.len() == out.len());
    if x.iter().any(|v| !v.is_finite()) {
        for o in out.iter_mut() {
            *o = f32::NAN;
        }
        return None;
    }
    let max_abs = x.iter().fold(0.0f64, |a, &v| a.max(v.abs()));
    let e = select_scale_exp_reference(max_abs);
    let scale = 2f64.powi(e);
    for (o, &v) in out.iter_mut().zip(x) {
        let m = v.abs() / scale; // exact power-of-two divide
        let mut best = 0usize;
        let mut best_d = f64::INFINITY;
        for (i, &c) in E2M1_MAGNITUDES.iter().enumerate() {
            let d = (m - c).abs();
            // Near any contested midpoint both distances are Sterbenz-
            // exact, so the comparison (and the tie test) is exact.
            if d < best_d || (d == best_d && i % 2 == 0 && best % 2 == 1) {
                best = i;
                best_d = d;
            }
        }
        let q = (E2M1_MAGNITUDES[best] * scale) as f32;
        *o = if v.is_sign_negative() { -q } else { q };
    }
    Some(e)
}

/// The 4-bit code one element commits to at a pinned scale
/// (`sign << 3 | magnitude index`).  Test/conformance helper; agrees
/// with [`quantize_element`] via [`decode`].
pub fn encode_element(x: f64, scale_exp: i32) -> u8 {
    let m = e2m1_magnitude((x * pow2(-scale_exp)).abs());
    let idx = E2M1_MAGNITUDES.iter().position(|&c| c == m).unwrap() as u8;
    if x.is_sign_negative() {
        idx | 8
    } else {
        idx
    }
}

/// Decode a 4-bit E2M1 code at a scale exponent into its f32 value.
///
/// ```
/// use collage::numerics::block::decode;
/// assert_eq!(decode(0b0111, 0), 6.0); // top magnitude at scale 2⁰
/// assert_eq!(decode(0b1010, -3), -0.125); // -1.0 · 2⁻³
/// assert!(decode(0b1000, 5).is_sign_negative()); // -0 keeps its sign
/// ```
pub fn decode(code: u8, scale_exp: i32) -> f32 {
    debug_assert!(code < 16, "4-bit code out of range: {code}");
    debug_assert!((SCALE_E_MIN..=SCALE_E_MAX).contains(&scale_exp));
    let v = (E2M1_MAGNITUDES[(code & 7) as usize] * pow2(scale_exp)) as f32;
    if code & 8 != 0 {
        -v
    } else {
        v
    }
}

/// Quantize a whole vector on the global 32-element block grid (the last
/// block may be short) — the layout every block-format consumer shares
/// with the fused kernels (`ACCUM_CHUNK % BLOCK == 0`, so chunk sharding
/// preserves it).
pub fn quantize_slice(x: &[f64], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    for (xs, os) in x.chunks(BLOCK).zip(out.chunks_mut(BLOCK)) {
        quantize_block(xs, os);
    }
}

/// Block-quantize an f32 vector in place (θ initialization, target
/// construction): widen each block to f64 (exact) and requantize.
pub fn quantize_slice_in_place(v: &mut [f32]) {
    let mut buf = [0.0f64; BLOCK];
    for blk in v.chunks_mut(BLOCK) {
        for (b, &x) in buf.iter_mut().zip(blk.iter()) {
            *b = x as f64;
        }
        let n = blk.len();
        quantize_block(&buf[..n], blk);
    }
}

/// True iff every 32-block of `v` is a fixpoint of block quantization —
/// the block-format strengthening of element-wise `representable` checks
/// (a vector can be element-wise on-grid yet have a block whose nonzero
/// magnitudes span more than one shared scale).  Quantizer outputs always
/// pass: the quantized max re-derives the same scale (it lands on
/// `4·2^e` or `6·2^e`), and on-grid elements re-round to themselves.
pub fn block_consistent(v: &[f32]) -> bool {
    let mut buf = [0.0f64; BLOCK];
    let mut out = [0.0f32; BLOCK];
    for blk in v.chunks(BLOCK) {
        let n = blk.len();
        for i in 0..n {
            buf[i] = blk[i] as f64;
        }
        quantize_block(&buf[..n], &mut out[..n]);
        for i in 0..n {
            let same = out[i].to_bits() == blk[i].to_bits()
                || (out[i].is_nan() && blk[i].is_nan());
            if !same {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::format::MXFP4;
    use crate::util::rng::Rng;

    fn assert_block_eq(fast: &[f32], slow: &[f32], ctx: &str) {
        for (i, (a, b)) in fast.iter().zip(slow).enumerate() {
            assert!(
                a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan()),
                "{ctx}: element {i}: fast {a:e} ({:08x}) != reference {b:e} ({:08x})",
                a.to_bits(),
                b.to_bits()
            );
        }
    }

    #[test]
    fn tie_table_and_clamp_at_unit_scale() {
        // Pin the scale to 0 with a max element of 6; check every tie
        // midpoint and the clamp zone against the documented table.
        let cases: [(f64, f32); 12] = [
            (0.25, 0.0),
            (0.26, 0.5),
            (0.75, 1.0),
            (1.25, 1.0),
            (1.26, 1.5),
            (1.75, 2.0),
            (2.5, 2.0),
            (2.51, 3.0),
            (3.5, 4.0),
            (5.0, 4.0),
            (5.01, 6.0),
            (7.9, 6.0), // clamp: the block max itself saturates to 6
        ];
        for (x, want) in cases {
            let input = [6.0, x, -x];
            let mut fast = [0.0f32; 3];
            let mut slow = [0.0f32; 3];
            assert_eq!(quantize_block(&input, &mut fast), Some(0), "x={x}");
            assert_eq!(quantize_block_reference(&input, &mut slow), Some(0));
            assert_block_eq(&fast, &slow, &format!("x={x}"));
            assert_eq!(fast[1], want, "x={x}");
            assert_eq!(fast[2], -want, "x={x}");
        }
    }

    #[test]
    fn pinned_all_zero_nan_and_subnormal_blocks() {
        // All-zero: scale exponent 0, elements ±0 with signs preserved.
        let mut out = [1.0f32; 4];
        assert_eq!(quantize_block(&[0.0, -0.0, 0.0, -0.0], &mut out), Some(0));
        assert_eq!(out[0].to_bits(), 0.0f32.to_bits());
        assert_eq!(out[1].to_bits(), (-0.0f32).to_bits());
        // Any NaN or inf poisons the whole block.
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let mut out = [0.0f32; 3];
            assert_eq!(quantize_block(&[1.0, bad, 2.0], &mut out), None);
            assert!(out.iter().all(|v| v.is_nan()), "bad={bad}");
            let mut slow = [0.0f32; 3];
            assert_eq!(quantize_block_reference(&[1.0, bad, 2.0], &mut slow), None);
            assert!(slow.iter().all(|v| v.is_nan()));
        }
        // A lone tiny value: the scale clamps at SCALE_E_MIN and the
        // element rounds on the 2⁻¹²⁷-floor grid.
        let mut out = [0.0f32; 2];
        let e = quantize_block(&[0.0, 2f64.powi(-140)], &mut out).unwrap();
        assert_eq!(e, SCALE_E_MIN);
        assert_eq!(out[1], 0.0); // 2⁻¹⁴⁰ · 2¹²⁶ = 2⁻¹⁴ ≤ 0.25 → 0
        let e = quantize_block(&[0.0, 2f64.powi(-127)], &mut out).unwrap();
        assert_eq!(e, SCALE_E_MIN);
        assert_eq!(out[1], 2f32.powi(-127)); // 0.5 on the floor grid
    }

    #[test]
    fn fast_matches_reference_on_seeded_blocks() {
        let mut rng = Rng::new(0xB10C_F4, 0);
        let mut x = [0.0f64; BLOCK];
        let mut fast = [0.0f32; BLOCK];
        let mut slow = [0.0f32; BLOCK];
        for round in 0..2000 {
            let scale = 10f64.powi(rng.below(61) as i32 - 30);
            for v in x.iter_mut() {
                *v = rng.normal() * scale;
            }
            let ef = quantize_block(&x, &mut fast);
            let es = quantize_block_reference(&x, &mut slow);
            assert_eq!(ef, es, "round {round}");
            assert_block_eq(&fast, &slow, &format!("round {round}"));
        }
    }

    #[test]
    fn idempotent_and_on_element_grid() {
        let mut rng = Rng::new(0xB10C_F5, 0);
        let mut x = [0.0f64; BLOCK];
        let mut q1 = [0.0f32; BLOCK];
        for _ in 0..500 {
            for v in x.iter_mut() {
                *v = rng.normal() * 3.0;
            }
            let e1 = quantize_block(&x, &mut q1).unwrap();
            assert!(q1.iter().all(|&v| MXFP4.representable(v)));
            assert!(block_consistent(&q1));
            // Requantizing the decoded block reselects the same scale.
            let wide: Vec<f64> = q1.iter().map(|&v| v as f64).collect();
            let mut q2 = [0.0f32; BLOCK];
            assert_eq!(quantize_block(&wide, &mut q2), Some(e1));
            assert_block_eq(&q2, &q1, "idempotence");
        }
    }

    #[test]
    fn encode_decode_agree_with_quantize() {
        let mut rng = Rng::new(0xB10C_F6, 0);
        for _ in 0..2000 {
            let e = rng.below((SCALE_E_MAX - SCALE_E_MIN + 1) as u64) as i32 + SCALE_E_MIN;
            let x = rng.normal() * 8.0 * 2f64.powi(e);
            let code = encode_element(x, e);
            let direct = quantize_element(x, e);
            let via_code = decode(code, e);
            assert_eq!(via_code.to_bits(), direct.to_bits(), "x={x:e} e={e}");
        }
    }
}
