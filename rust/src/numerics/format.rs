//! Floating-point format descriptors (paper Appendix A, Table 9) and
//! round-to-nearest-even quantization into each format.
//!
//! # The rounding contract
//!
//! Every quantizer in this module implements IEEE-754 **round to nearest,
//! ties to even** onto the target format's grid, and all of them agree
//! bitwise.  The contract, which every low-precision result in this repo
//! leans on (surveys of low-precision training single out rounding-mode
//! implementation detail as the first place reproductions diverge):
//!
//! * **Nearest / ties-to-even.**  A value exactly halfway between two
//!   adjacent representables rounds to the one with an even mantissa
//!   (e.g. `1 + 2⁻⁸` ties down to `1.0` in bf16).
//! * **Subnormals.**  The grid extends below `2^e_min` with the fixed
//!   quantum `2^(e_min − m)`; inputs under half the smallest subnormal
//!   round to (signed) zero, and the zero's sign is preserved.
//! * **Overflow.**  Values that round above [`FloatFormat::max_finite`]
//!   become `±inf` — except on saturating formats (FP8-E4M3 per the OCP
//!   spec has no infinities), where they clamp to `±max_finite`.  E4M3
//!   additionally reclaims the all-ones exponent for finite values, so its
//!   top binade is finite (`max_finite = 448`, the `1.875·2⁸` code point
//!   being NaN).
//! * **NaN** propagates as the canonical quiet `f32::NAN`.
//!
//! Two implementations provide the contract:
//!
//! * [`FloatFormat::round`] / [`FloatFormat::round_nearest_f64`] — the
//!   **bit-parallel fast paths**: shift + round-to-even on the raw
//!   mantissa (the [`bf16_round`] trick generalized to any
//!   exponent/mantissa split), no `log2`/`floor`/`powi` in sight.
//! * [`FloatFormat::round_nearest_f64_reference`] — the original
//!   arithmetic quantizer (exponent via `log2`, scale, round, rescale),
//!   retained as the executable specification.
//!
//! The fast paths are **bitwise identical** to the reference for every
//! input: `tests/rounding_equivalence.rs` enforces this on seeded samples
//! plus hand-picked boundary cases in tier 1, and exhaustively over all
//! 2³² `f32` bit patterns behind `#[ignore]`.

/// A binary floating-point format described by its exponent/mantissa split
/// (IEEE-754 style, radix 2, with subnormals).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FloatFormat {
    pub name: &'static str,
    pub exp_bits: u32,
    /// Explicit mantissa (significand fraction) bits; precision P = m + 1.
    pub mantissa_bits: u32,
    /// Storage width in bytes (for the memory model).
    pub bytes: usize,
    /// Whether overflow saturates to the max finite value instead of ±inf
    /// (FP8-E4M3 per the OCP spec has no infinities).
    pub saturating: bool,
    /// Block size for block-scaled (microscaling) formats — the number of
    /// consecutive elements sharing one power-of-two scale — or 0 for
    /// plain element-wise formats.  When nonzero, this descriptor is the
    /// **element-wise view** (the union of every block grid; see
    /// [`crate::numerics::block`]) and quantization must go through the
    /// block quantizer, not `round`.
    pub block: usize,
}

/// bfloat16: 8 exponent bits, 7 mantissa bits — FP32's range, tiny precision.
pub const BF16: FloatFormat = FloatFormat {
    name: "bf16",
    exp_bits: 8,
    mantissa_bits: 7,
    bytes: 2,
    saturating: false,
    block: 0,
};
/// IEEE half precision.
pub const FP16: FloatFormat = FloatFormat {
    name: "fp16",
    exp_bits: 5,
    mantissa_bits: 10,
    bytes: 2,
    saturating: false,
    block: 0,
};
/// FP8 E4M3 (saturating, no inf).
pub const FP8E4M3: FloatFormat = FloatFormat {
    name: "fp8e4m3",
    exp_bits: 4,
    mantissa_bits: 3,
    bytes: 1,
    saturating: true,
    block: 0,
};
/// FP8 E5M2.
pub const FP8E5M2: FloatFormat = FloatFormat {
    name: "fp8e5m2",
    exp_bits: 5,
    mantissa_bits: 2,
    bytes: 1,
    saturating: false,
    block: 0,
};
/// IEEE single precision (identity quantizer over f32 containers).
pub const FP32: FloatFormat = FloatFormat {
    name: "fp32",
    exp_bits: 8,
    mantissa_bits: 23,
    bytes: 4,
    saturating: false,
    block: 0,
};
/// MXFP4 (OCP microscaling): E2M1 elements sharing a per-32-element E8M0
/// power-of-two scale.  This descriptor is the **element-wise view**: the
/// union of every block grid is exactly an `exp_bits: 8, mantissa_bits: 1`
/// grid (every decodable value has ≤ 2 significant bits, down to the
/// subnormal 2⁻¹²⁷ and up to `max_finite = 1.5·2¹²⁷`), so `round` /
/// `representable` / `ulp` describe the decodable set unchanged.  True
/// quantization — shared max-abs scale selection per block — lives in
/// [`crate::numerics::block`].  `bytes: 1` rounds up the true 4.25
/// bits/element; `saturating` is false because the element-wise overflow
/// path is unreachable (block scales clamp at 2¹²⁵, elements at 6·2¹²⁵).
pub const MXFP4: FloatFormat = FloatFormat {
    name: "mxfp4",
    exp_bits: 8,
    mantissa_bits: 1,
    bytes: 1,
    saturating: false,
    block: 32,
};

/// All **element-wise** formats (Table 9 order).  Block-scaled formats
/// ([`MXFP4`]) are deliberately not listed: they support a restricted
/// scheme set and quantize per block, so sweeps over this array would
/// apply element-wise semantics they don't have.  The parser accepts
/// them by name regardless.
pub const ALL_FORMATS: [FloatFormat; 5] = [FP32, FP16, BF16, FP8E4M3, FP8E5M2];

/// The canonical string → format mapping used by the CLI, `RunConfig` JSON
/// and the artifact manifest (one parser for the whole repo; the satellite
/// of the `PrecisionPlan` redesign).  Accepts the `name` of every entry in
/// [`ALL_FORMATS`] plus a few common aliases.
impl std::str::FromStr for FloatFormat {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        for f in ALL_FORMATS {
            if f.name == s {
                return Ok(f);
            }
        }
        Ok(match s {
            "f32" | "float32" => FP32,
            "f16" | "half" | "float16" => FP16,
            "bfloat16" => BF16,
            "e4m3" | "fp8" => FP8E4M3,
            "e5m2" => FP8E5M2,
            "mxfp4" | "fp4" | "mx4" => MXFP4,
            other => anyhow::bail!(
                "unknown float format {other:?} (fp32|fp16|bf16|fp8e4m3|fp8e5m2|mxfp4)"
            ),
        })
    }
}

impl std::fmt::Display for FloatFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name)
    }
}

impl FloatFormat {
    /// Exponent bias.
    pub fn bias(&self) -> i32 {
        (1 << (self.exp_bits - 1)) - 1
    }

    /// Minimum normal exponent e_min.
    pub fn e_min(&self) -> i32 {
        1 - self.bias()
    }

    /// Maximum normal exponent e_max.  Saturating formats (E4M3, OCP)
    /// reclaim the all-ones exponent for finite values (only the all-ones
    /// mantissa encodes NaN), extending the range by one binade.
    pub fn e_max(&self) -> i32 {
        self.bias() + if self.saturating { 1 } else { 0 }
    }

    /// Largest finite value.
    pub fn max_finite(&self) -> f64 {
        let frac = 2.0 - 2f64.powi(-(self.mantissa_bits as i32));
        // E4M3 sacrifices its top mantissa code point to NaN: max is
        // 1.75 * 2^8 = 448 rather than 1.875 * 2^8.
        let frac = if self.saturating { frac - 2f64.powi(-(self.mantissa_bits as i32)) } else { frac };
        frac * 2f64.powi(self.e_max())
    }

    /// Largest finite value as an `f32` (exact for every format here),
    /// built by bit construction — no `powi` on the hot path.
    #[inline]
    pub fn max_finite_f32(&self) -> f32 {
        let m = self.mantissa_bits as i32;
        let frac = if self.saturating {
            2.0 - 2.0 * pow2f(-m) // E4M3: top mantissa code point is NaN
        } else {
            2.0 - pow2f(-m)
        };
        frac * pow2f(self.e_max())
    }

    /// Unit in the last place of `x` (Def. 3.1):
    /// `ulp(x) = 2^(max(e, e_min) - mantissa_bits)`.
    ///
    /// The binade exponent comes straight from the `f64` exponent bits of
    /// `|x|` (exact — `f32 → f64` widening is lossless and turns every f32
    /// subnormal into a normal f64), replacing the previous
    /// `log2().floor()` + fixup.  Non-finite `x` yields `+inf`.
    pub fn ulp(&self, x: f32) -> f64 {
        let m = self.mantissa_bits as i32;
        if x == 0.0 {
            return pow2f64(self.e_min() - m);
        }
        if !x.is_finite() {
            return f64::INFINITY;
        }
        let e = (((x.abs() as f64).to_bits() >> 52) & 0x7FF) as i32 - 1023;
        pow2f64(e.max(self.e_min()) - m)
    }

    /// `ulp(1.0)` — the Table 9 column.
    pub fn ulp_one(&self) -> f64 {
        2f64.powi(-(self.mantissa_bits as i32))
    }

    /// Round an f64 to this format with round-to-nearest-even, returning an
    /// f32 container.  Handles zeros, subnormals, overflow and NaN.
    ///
    /// This is the bit-parallel fast path (see the module docs for the
    /// rounding contract); it is bitwise identical to
    /// [`FloatFormat::round_nearest_f64_reference`] for every input.
    #[inline]
    pub fn round_nearest_f64(&self, x: f64) -> f32 {
        if self.mantissa_bits == 23 && self.exp_bits == 8 {
            return x as f32; // FP32: rust f64→f32 cast is RN-even
        }
        self.round_bits_f64(x)
    }

    /// Bit-parallel RN-even core: shift + round-to-even on the raw f64
    /// mantissa (the [`bf16_round`] trick generalized to any
    /// exponent/mantissa split), handling subnormals, signed zeros,
    /// overflow-to-inf / E4M3 saturation, and NaN.
    #[inline]
    fn round_bits_f64(&self, x: f64) -> f32 {
        let bits = x.to_bits();
        let sign_bit = ((bits >> 63) as u32) << 31;
        let biased = ((bits >> 52) & 0x7FF) as i32;
        let man = bits & 0x000F_FFFF_FFFF_FFFF;
        if biased == 0x7FF {
            // NaN propagates canonically; ±inf overflows (or saturates).
            return if man != 0 { f32::NAN } else { self.overflow_value(sign_bit) };
        }
        if biased == 0 {
            // ±0, and f64 subnormals — far below every target's grid.
            return f32::from_bits(sign_bit);
        }
        let e = biased - 1023; // binade exponent: 2^e <= |x| < 2^(e+1)
        if e > self.e_max() {
            return self.overflow_value(sign_bit);
        }
        let m = self.mantissa_bits as i32;
        // Grid quantum 2^q; pinned at 2^(e_min − m) in the subnormal range.
        let q = e.max(self.e_min()) - m;
        // |x| = sig · 2^(e−52); rounding to a multiple of 2^q drops the low
        // `shift` significand bits.
        let shift = q - (e - 52);
        if shift >= 54 {
            return f32::from_bits(sign_bit); // |x| < quantum/2
        }
        let sig = man | (1u64 << 52); // implicit leading bit
        let half = 1u64 << (shift - 1);
        let rem = sig & ((half << 1) - 1);
        let mut keep = sig >> shift;
        if rem > half || (rem == half && keep & 1 == 1) {
            keep += 1; // round up; a carry into the next binade is fine
        }
        if keep == 0 {
            return f32::from_bits(sign_bit);
        }
        // Overflow is only reachable in the top binade (below it, even a
        // carry to keep = 2^(m+1) lands on 2^(e+1) <= 2^e_max < max), where
        // the largest in-range significand is 2^(m+1) − 1, minus one more
        // code point on saturating formats (E4M3's top mantissa is NaN).
        // An integer test keeps `max_finite` recomputation off the hot path.
        if e == self.e_max() && keep > (1u64 << (m + 1)) - 1 - self.saturating as u64 {
            return self.overflow_value(sign_bit);
        }
        // v = keep · 2^q, exact in f32: keep has ≤ m+2 significant bits and
        // every grid point of our formats is f32-representable.  The split
        // exponent keeps the construction exact when the grid dips into the
        // f32 subnormal range (bf16 subnormals reach 2⁻¹³³ < 2⁻¹²⁶).
        let q1 = q.max(-126);
        let v = (keep as f32) * pow2f(q1) * pow2f(q - q1);
        f32::from_bits(sign_bit | v.to_bits())
    }

    /// What an overflowing magnitude becomes: `±inf`, or `±max_finite` on
    /// saturating formats (E4M3 has no infinities).
    #[inline]
    fn overflow_value(&self, sign_bit: u32) -> f32 {
        let mag = if self.saturating {
            self.max_finite_f32().to_bits()
        } else {
            0x7F80_0000 // +inf
        };
        f32::from_bits(sign_bit | mag)
    }

    /// The executable specification of the rounding contract: the original
    /// arithmetic quantizer (exponent via `log2`, scale by the quantum,
    /// round ties-to-even, rescale).  ~10× the cost of the bit-parallel
    /// path — kept only as the oracle for `tests/rounding_equivalence.rs`.
    pub fn round_nearest_f64_reference(&self, x: f64) -> f32 {
        if self.mantissa_bits == 23 && self.exp_bits == 8 {
            return x as f32; // FP32: rust f64→f32 cast is RN-even
        }
        if x.is_nan() {
            return f32::NAN;
        }
        if x == 0.0 {
            return if x.is_sign_negative() { -0.0 } else { 0.0 };
        }
        if x.is_infinite() {
            return if self.saturating {
                (self.max_finite() as f32).copysign(x as f32)
            } else {
                x as f32
            };
        }
        let sign = if x < 0.0 { -1.0f64 } else { 1.0 };
        let m = x.abs();
        let e = fixup_exponent(m, m.log2().floor() as i32);
        // Quantum: distance between representable values in x's binade
        // (subnormal quantum below e_min).
        let q_exp = e.max(self.e_min()) - self.mantissa_bits as i32;
        let quantum = 2f64.powi(q_exp);
        let scaled = m / quantum; // exact (power-of-two divide)
        let rounded = round_ties_even(scaled);
        let mut v = rounded * quantum;
        // Rounding may push into the next binade (e.g. 1.996 -> 2.0): still
        // correct since the next binade's grid contains this value.
        if v > self.max_finite() {
            v = if self.saturating { self.max_finite() } else { f64::INFINITY };
        }
        (sign * v) as f32
    }

    /// Round an f32 into this format with RN-even — **the** quantization
    /// entry point, dispatching to a bit-parallel fast path per format
    /// (`u32` bit trick for bf16, identity for fp32, the generalized
    /// mantissa shift of [`FloatFormat::round_nearest_f64`] for fp16/fp8;
    /// the `f32 → f64` widening is exact, so no double rounding occurs).
    ///
    /// See the module docs for the full rounding contract.
    ///
    /// ```
    /// use collage::numerics::format::{BF16, FP16, FP8E4M3, FP8E5M2};
    /// // Ties round to even: 1 + 2⁻⁸ is halfway to the next bf16 grid point.
    /// assert_eq!(BF16.round(1.0 + 2f32.powi(-8)), 1.0);
    /// // fp16 overflows to inf above its max finite value (65504)...
    /// assert_eq!(FP16.round(65504.0), 65504.0);
    /// assert_eq!(FP16.round(65520.0), f32::INFINITY);
    /// // ...E5M2 keeps inf too, but E4M3 saturates (the OCP spec has no inf).
    /// assert_eq!(FP8E5M2.round(1e6), f32::INFINITY);
    /// assert_eq!(FP8E4M3.round(1e6), 448.0);
    /// // Subnormals: 2⁻²⁴ is fp16's smallest subnormal; half of it ties to 0.
    /// assert_eq!(FP16.round(2f32.powi(-24)), 2f32.powi(-24));
    /// assert_eq!(FP16.round(2f32.powi(-25)), 0.0);
    /// // Signed zero survives.
    /// assert!(FP8E4M3.round(-0.0).is_sign_negative());
    /// ```
    #[inline]
    pub fn round(&self, x: f32) -> f32 {
        if self.exp_bits == 8 {
            if self.mantissa_bits == 23 {
                return x;
            }
            if self.mantissa_bits == 7 {
                return bf16_round(x);
            }
        }
        self.round_bits_f64(x as f64)
    }

    /// Round an f32 to this format with RN-even.  Alias of
    /// [`FloatFormat::round`], kept for the existing call sites.
    #[inline]
    pub fn round_nearest(&self, x: f32) -> f32 {
        self.round(x)
    }

    /// [`FloatFormat::round`] over a lane of 8 **independent** elements —
    /// the single batched rounding entry point the lane kernels in
    /// `optim/kernels.rs` are built on.
    ///
    /// Bitwise contract: `round_x8(x)[l] == round(x[l])` for every lane
    /// and every format (`tests/round_x8.rs` pins it, including NaN
    /// canonicalization).  The per-lane math is the same branchless
    /// shift+round-to-even core as the scalar path — bf16 keeps its `u32`
    /// bit trick (here in branch-free select form so all 8 lanes run the
    /// same instruction sequence), fp32 is the identity, and everything
    /// else runs the generalized mantissa shift per lane.  Batching is
    /// profitable because one element's rounding never feeds another's:
    /// the compiler can vectorize across lanes even though the Fast2Sum
    /// dependency *chains* inside one element cannot be.
    ///
    /// ```
    /// use collage::numerics::format::{BF16, FP8E4M3};
    /// let x = [1.0f32, 1.0 + 2f32.powi(-8), -0.0, 1e6, 3.14, -3.14, 448.0, 0.1];
    /// let batched = BF16.round_x8(x);
    /// for l in 0..8 {
    ///     assert_eq!(batched[l].to_bits(), BF16.round(x[l]).to_bits());
    /// }
    /// // E4M3 saturates inside the lane body exactly like the scalar path.
    /// assert_eq!(FP8E4M3.round_x8(x)[3], 448.0);
    /// ```
    #[inline]
    pub fn round_x8(&self, x: [f32; 8]) -> [f32; 8] {
        if self.exp_bits == 8 {
            if self.mantissa_bits == 23 {
                return x;
            }
            if self.mantissa_bits == 7 {
                // Branch-free 8-wide form of `bf16_round`: round-to-even via
                // the carry trick, NaN lanes selected to the canonical quiet
                // NaN (same canonicalization as the scalar guard branch).
                return std::array::from_fn(|l| {
                    let bits = x[l].to_bits();
                    let rounded = bits.wrapping_add(0x7FFF + ((bits >> 16) & 1)) & 0xFFFF_0000;
                    let is_nan = (bits & 0x7FFF_FFFF) > 0x7F80_0000;
                    f32::from_bits(if is_nan { f32::NAN.to_bits() } else { rounded })
                });
            }
        }
        std::array::from_fn(|l| self.round_bits_f64(x[l] as f64))
    }

    /// [`FloatFormat::round_nearest_f64`] over a lane of 8 independent
    /// elements — the f64-domain companion of [`FloatFormat::round_x8`],
    /// used by the lane kernels for exact-then-round chain steps whose
    /// exact value lives in f64.  Same bitwise contract:
    /// `round_nearest_f64_x8(x)[l] == round_nearest_f64(x[l])` per lane.
    #[inline]
    pub fn round_nearest_f64_x8(&self, x: [f64; 8]) -> [f32; 8] {
        if self.exp_bits == 8 && self.mantissa_bits == 23 {
            return std::array::from_fn(|l| x[l] as f32);
        }
        std::array::from_fn(|l| self.round_bits_f64(x[l]))
    }

    /// True iff `x` is exactly representable in this format.
    pub fn representable(&self, x: f32) -> bool {
        x.is_nan() || self.round_nearest(x) == x
    }

    /// The next representable value above `x` (toward +inf).
    ///
    /// Correct across binade boundaries for both signs: going up from a
    /// negative power of two enters a binade with half the spacing, which
    /// a naive `x + ulp(x)` step (ulp measured on |x|) would overshoot.
    ///
    /// At the top of the grid the behavior follows the format's overflow
    /// semantics: `next_up(max_finite)` is `+inf` on IEEE-style formats
    /// but **stays `max_finite` on saturating formats** (E4M3 per the OCP
    /// spec has no infinities — stepping to inf would mint a value the
    /// format cannot represent).  Inputs at or beyond `max_finite`
    /// (including `+inf`) clamp the same way.
    pub fn next_up(&self, x: f32) -> f32 {
        if x.is_nan() {
            return x;
        }
        if x < 0.0 {
            return -self.next_down(-x);
        }
        let max = self.max_finite_f32();
        if x >= max {
            // Top of the grid: saturate or overflow, never a finite value
            // above max (the old arithmetic path happened to saturate for
            // representable x but returned values *below* x for
            // non-representable inputs beyond max).
            return if self.saturating { max } else { f32::INFINITY };
        }
        // For non-negative x the spacing above x is exactly ulp(x).
        let u = self.ulp(x) as f32;
        let mut y = self.round_nearest(x + u);
        if y <= x {
            y = self.round_nearest(x + 2.0 * u);
        }
        y
    }

    /// The next representable value below `x` (toward -inf).  Inputs above
    /// `max_finite` (including `+inf`) return `max_finite` — the largest
    /// grid point below them.
    pub fn next_down(&self, x: f32) -> f32 {
        if x.is_nan() {
            return x;
        }
        if x < 0.0 {
            return -self.next_up(-x);
        }
        if x == 0.0 {
            return -(self.ulp(0.0) as f32); // largest negative subnormal
        }
        let max = self.max_finite_f32();
        if x > max {
            return max;
        }
        // Spacing below x is ulp(x), except just above a binade boundary
        // (x = 2^e) where the grid below is twice as fine: try the half
        // step first.  Both candidates are exact dyadics in f64 and f32.
        let u = self.ulp(x);
        let half = x as f64 - u / 2.0;
        if self.representable(half as f32) && (half as f32) < x {
            return half as f32;
        }
        (x as f64 - u) as f32
    }
}

/// `2^q` as an f32 by direct bit construction (normal range only).
#[inline]
fn pow2f(q: i32) -> f32 {
    debug_assert!((-126..=127).contains(&q), "pow2f exponent {q} out of range");
    f32::from_bits(((q + 127) as u32) << 23)
}

/// `2^q` as an f64 by direct bit construction (normal range only).
#[inline]
fn pow2f64(q: i32) -> f64 {
    debug_assert!((-1022..=1023).contains(&q), "pow2f64 exponent {q} out of range");
    f64::from_bits(((q + 1023) as u64) << 52)
}

/// `log2().floor()` misrounds just below powers of two; nudge the exponent
/// so that `2^e <= m < 2^(e+1)`.
fn fixup_exponent(m: f64, mut e: i32) -> i32 {
    if 2f64.powi(e) > m {
        e -= 1;
    }
    if 2f64.powi(e + 1) <= m {
        e += 1;
    }
    e
}

/// Round-half-to-even for non-negative f64 (values well below 2^52).
fn round_ties_even(x: f64) -> f64 {
    let f = x.floor();
    let r = x - f;
    if r > 0.5 {
        f + 1.0
    } else if r < 0.5 {
        f
    } else if (f as i64) % 2 == 0 {
        f
    } else {
        f + 1.0
    }
}

/// Fast bf16 RN-even on the raw f32 bits (the hardware algorithm).
#[inline]
pub fn bf16_round(x: f32) -> f32 {
    if x.is_nan() {
        return f32::NAN;
    }
    let bits = x.to_bits();
    let rounding_bias = 0x7FFF + ((bits >> 16) & 1);
    let rounded = bits.wrapping_add(rounding_bias) & 0xFFFF_0000;
    f32::from_bits(rounded)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_name_roundtrip() {
        for f in ALL_FORMATS {
            let back: FloatFormat = f.name.parse().unwrap();
            assert_eq!(back, f, "{}", f.name);
        }
        assert!("fp12".parse::<FloatFormat>().is_err());
    }

    #[test]
    fn mxfp4_elementwise_view() {
        assert_eq!("mxfp4".parse::<FloatFormat>().unwrap(), MXFP4);
        assert_eq!("fp4".parse::<FloatFormat>().unwrap(), MXFP4);
        assert_eq!(MXFP4.block, 32);
        // The element-wise grid brackets the decodable set exactly:
        // 0.5·2⁻¹²⁶ = 2⁻¹²⁷ is the smallest subnormal, 6·2¹²⁵ = 1.5·2¹²⁷
        // the max (see numerics::block).
        assert_eq!(MXFP4.ulp(0.0), 2f64.powi(-127));
        assert_eq!(MXFP4.max_finite(), 1.5 * 2f64.powi(127));
        // ≤2-significant-bit values are representable; 3-bit ones are not.
        for e in [-126, -5, 0, 60] {
            assert!(MXFP4.representable(1.0 * 2f32.powi(e)), "2^{e}");
            assert!(MXFP4.representable(1.5 * 2f32.powi(e)), "1.5·2^{e}");
            assert!(!MXFP4.representable(1.25 * 2f32.powi(e)), "1.25·2^{e}");
        }
        // The subnormal floor: 2⁻¹²⁷ is on the grid, 1.5·2⁻¹²⁷ is below
        // the quantum and is not.
        assert!(MXFP4.representable(2f32.powi(-127)));
        assert!(!MXFP4.representable(1.5 * 2f32.powi(-127)));
        assert_eq!(MXFP4.round_nearest(5.0), 4.0); // tie to even on the grid
        assert_eq!(MXFP4.round_nearest(5.1), 6.0);
    }

    #[test]
    fn max_finite_f32_matches_f64_and_pow2_helpers() {
        for f in ALL_FORMATS {
            assert_eq!(f.max_finite_f32() as f64, f.max_finite(), "{}", f.name);
        }
        for q in [-126, -24, -1, 0, 1, 13, 127] {
            assert_eq!(pow2f(q) as f64, 2f64.powi(q), "pow2f({q})");
        }
        for q in [-1022, -149, -133, -24, 0, 52, 1023] {
            assert_eq!(pow2f64(q), 2f64.powi(q), "pow2f64({q})");
        }
    }

    #[test]
    fn table9_ulp_one() {
        // Paper Table 9.
        assert_eq!(FP32.ulp_one(), 2f64.powi(-23));
        assert_eq!(FP16.ulp_one(), 2f64.powi(-10));
        assert_eq!(BF16.ulp_one(), 2f64.powi(-7));
        assert_eq!(FP8E4M3.ulp_one(), 2f64.powi(-3));
        assert_eq!(FP8E5M2.ulp_one(), 2f64.powi(-2));
    }

    #[test]
    fn bf16_examples_from_paper() {
        // 0.999 -> 1.0 (Sec. 2.2); 0.1 rounds to ~0.1001 (Sec. 3.1).
        assert_eq!(BF16.round_nearest(0.999), 1.0);
        let r = BF16.round_nearest(0.1);
        assert!((r - 0.1).abs() < 1e-3 && r != 0.1);
        // ulp(200) = 1 -> 200 + 0.1 == 200 (Sec. 3.1 remark).
        assert_eq!(BF16.ulp(200.0), 1.0);
        assert_eq!(BF16.round_nearest(200.0 + 0.1), 200.0);
    }

    #[test]
    fn bf16_fast_matches_generic() {
        // The bit-trick rounding must agree with the generic f64 quantizer.
        let mut rng = crate::util::rng::Rng::new(1, 0);
        for _ in 0..20_000 {
            let x = f32::from_bits(rng.next_u32());
            if x.is_nan() {
                continue;
            }
            let fast = bf16_round(x);
            let slow = BF16.round_nearest_f64_reference(x as f64);
            assert!(
                fast == slow || (fast.is_infinite() && slow.is_infinite() && fast == slow),
                "x={x:e} bits={:08x}: fast={fast:e} slow={slow:e}",
                x.to_bits()
            );
        }
    }

    #[test]
    fn ties_round_to_even() {
        // 1 + 2^-8 is exactly between 1.0 and 1 + 2^-7 in bf16 -> even (1.0)
        assert_eq!(BF16.round_nearest(1.0 + 2f32.powi(-8)), 1.0);
        // 1 + 3*2^-8 is between 1+2^-7 and 1+2^-6 -> even mantissa (1+2^-6)
        assert_eq!(BF16.round_nearest(1.0 + 3.0 * 2f32.powi(-8)), 1.0 + 2f32.powi(-6));
    }

    #[test]
    fn e4m3_saturates() {
        assert_eq!(FP8E4M3.max_finite(), 448.0);
        assert_eq!(FP8E4M3.round_nearest(1e6), 448.0);
        assert_eq!(FP8E4M3.round_nearest(-1e6), -448.0);
        assert_eq!(FP8E5M2.round_nearest(1e6), f32::INFINITY);
    }

    #[test]
    fn fp16_known_values() {
        assert_eq!(FP16.round_nearest(1.0), 1.0);
        assert_eq!(FP16.round_nearest(65504.0), 65504.0); // max finite
        assert_eq!(FP16.round_nearest(65520.0), f32::INFINITY);
        // subnormal: smallest positive fp16 is 2^-24
        assert_eq!(FP16.round_nearest(2f32.powi(-24)), 2f32.powi(-24));
        assert_eq!(FP16.round_nearest(2f32.powi(-26)), 0.0);
    }

    #[test]
    fn representable_closed_under_round() {
        let mut rng = crate::util::rng::Rng::new(2, 0);
        for fmt in [BF16, FP16, FP8E4M3, FP8E5M2] {
            for _ in 0..2000 {
                let x = (rng.normal() as f32) * 10f32.powi(rng.below(20) as i32 - 10);
                let r = fmt.round_nearest(x);
                if r.is_finite() {
                    assert!(fmt.representable(r), "{} {x:e} -> {r:e}", fmt.name);
                }
            }
        }
    }

    #[test]
    fn subnormal_zero_and_signs() {
        assert_eq!(BF16.round_nearest(0.0).to_bits(), 0.0f32.to_bits());
        assert_eq!(BF16.round_nearest(-0.0).to_bits(), (-0.0f32).to_bits());
        assert!(BF16.round_nearest(f32::NAN).is_nan());
    }

    #[test]
    fn next_up_down_at_binade_boundaries_both_signs() {
        // e5m2 around 4.0: grid ... 3.0, 3.5, 4.0, 5.0 ... — the spacing
        // halves below the boundary.
        assert_eq!(FP8E5M2.next_up(4.0), 5.0);
        assert_eq!(FP8E5M2.next_down(4.0), 3.5);
        assert_eq!(FP8E5M2.next_up(3.5), 4.0);
        assert_eq!(FP8E5M2.next_down(3.5), 3.0);
        // Negative mirror: next_up(-4.0) must be the *adjacent* -3.5.
        assert_eq!(FP8E5M2.next_up(-4.0), -3.5);
        assert_eq!(FP8E5M2.next_down(-4.0), -5.0);
        assert_eq!(FP8E5M2.next_up(-3.5), -3.0);
        // Around zero: adjacent subnormals.
        let minsub = FP8E5M2.ulp(0.0) as f32;
        assert_eq!(FP8E5M2.next_up(0.0), minsub);
        assert_eq!(FP8E5M2.next_down(0.0), -minsub);
        assert_eq!(FP8E5M2.next_down(minsub), 0.0);
        // bf16 spot check at a boundary: below 2.0 the spacing is 2⁻⁷.
        assert_eq!(BF16.next_down(2.0), 2.0 - 2f32.powi(-7));
        assert_eq!(BF16.next_up(-2.0), -(2.0 - 2f32.powi(-7)));
    }

    #[test]
    fn next_up_down_at_e4m3_max_normal_boundary() {
        // E4M3 saturates: there is no inf on its grid, so stepping up from
        // max_finite (448) must stay at 448 — never mint an inf — for both
        // signs, and the neighbour below max is the adjacent grid point
        // (416; 480 is the NaN code point, 432 is the rejected midpoint).
        assert_eq!(FP8E4M3.next_up(448.0), 448.0);
        assert!(FP8E4M3.next_up(448.0).is_finite());
        assert_eq!(FP8E4M3.next_down(448.0), 416.0);
        assert_eq!(FP8E4M3.next_up(416.0), 448.0);
        assert_eq!(FP8E4M3.next_down(-448.0), -448.0);
        assert!(FP8E4M3.next_down(-448.0).is_finite());
        assert_eq!(FP8E4M3.next_up(-448.0), -416.0);
        // Inputs beyond the grid (the old arithmetic path returned
        // non-representable values like 468 here) clamp to max_finite.
        assert_eq!(FP8E4M3.next_up(1e9), 448.0);
        assert_eq!(FP8E4M3.next_down(500.0), 448.0);
        assert_eq!(FP8E4M3.next_down(f32::INFINITY), 448.0);
        assert_eq!(FP8E4M3.next_up(f32::NEG_INFINITY), -448.0);
        // Non-saturating formats keep their IEEE semantics: nextUp(max) is
        // +inf and nextDown(+inf) is max.
        assert_eq!(FP8E5M2.next_up(57344.0), f32::INFINITY);
        assert_eq!(FP8E5M2.next_down(f32::INFINITY), 57344.0);
        assert_eq!(FP16.next_up(65504.0), f32::INFINITY);
        assert_eq!(FP16.next_down(f32::INFINITY), 65504.0);
        assert_eq!(BF16.next_down(f32::INFINITY), BF16.max_finite_f32());
        // NaN passes through both directions.
        assert!(FP8E4M3.next_up(f32::NAN).is_nan());
        assert!(FP8E4M3.next_down(f32::NAN).is_nan());
    }

    #[test]
    fn prop_next_up_down_are_adjacent() {
        // For random representable x: next_up(x) > x, next_down(x) < x,
        // and nothing representable sits strictly between x and either
        // neighbour (checked via the midpoint rounding to one of the two).
        let mut rng = crate::util::rng::Rng::new(9, 0);
        for fmt in [BF16, FP16, FP8E4M3, FP8E5M2] {
            for _ in 0..2000 {
                let x = fmt.round_nearest(
                    (rng.normal() as f32) * 10f32.powi(rng.below(9) as i32 - 4),
                );
                if !x.is_finite() {
                    continue;
                }
                let up = fmt.next_up(x);
                if up.is_finite() && up > x {
                    assert!(fmt.representable(up), "{} up({x:e})={up:e}", fmt.name);
                    let mid = fmt.round_nearest_f64((x as f64 + up as f64) / 2.0);
                    assert!(mid == x || mid == up, "{}: gap around {x:e}", fmt.name);
                }
                let down = fmt.next_down(x);
                if down.is_finite() && down < x {
                    assert!(fmt.representable(down), "{} down({x:e})={down:e}", fmt.name);
                    let mid = fmt.round_nearest_f64((x as f64 + down as f64) / 2.0);
                    assert!(mid == x || mid == down, "{}: gap around {x:e}", fmt.name);
                }
            }
        }
    }

    #[test]
    fn ulp_def_matches_spacing() {
        for x in [1.0f32, 1.5, 2.0, 3.0, 100.0, 0.007, 1e-20] {
            let u = BF16.ulp(x) as f32;
            let r = BF16.round_nearest(x);
            let up = BF16.next_up(r);
            if up.is_finite() && r > 0.0 {
                assert!(
                    (up - r) == u || (up - r) == 2.0 * u, // binade boundary
                    "x={x}: spacing {} vs ulp {u}",
                    up - r
                );
            }
        }
    }
}
