//! Multi-component-float (MCF) expansion algebra — paper Sec. 4.1 and
//! Appendix C, bit-exact against `python/compile/kernels/ref.py`.
//!
//! All functions take bf16-representable (or generic-format-representable)
//! values in f32 containers and apply the exact-then-round convention: the
//! exact operation is computed in f64 (always exact or innocuously
//! double-rounded for p ≤ 11 targets) and rounded once into the format.

use super::format::FloatFormat;
#[cfg(test)]
use super::format::BF16;

/// A length-2 expansion: the unevaluated sum `hi + lo` with non-overlapping
/// components, `|lo| <= ulp(hi)/2` (Priest 1991, Def. 2.1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Expansion {
    pub hi: f32,
    pub lo: f32,
}

impl Expansion {
    pub fn new(hi: f32, lo: f32) -> Self {
        Expansion { hi, lo }
    }

    pub fn zero() -> Self {
        Expansion { hi: 0.0, lo: 0.0 }
    }

    /// The evaluated (f64) value.
    pub fn value(&self) -> f64 {
        self.hi as f64 + self.lo as f64
    }

    /// Exact expansion of an f64 scalar in `fmt` (paper Table 1):
    /// `hi = RN(x)`, `lo = RN(x - hi)`.
    pub fn split_scalar(fmt: &FloatFormat, x: f64) -> Self {
        let hi = fmt.round_nearest_f64(x);
        let lo = fmt.round_nearest_f64(x - hi as f64);
        Expansion { hi, lo }
    }
}

/// The format-rounded binary operation `RN(a ∘ b)`.
#[inline]
fn rn(fmt: &FloatFormat, x: f64) -> f32 {
    fmt.round_nearest_f64(x)
}

/// Fast bf16 path used by the optimizer hot loop.
#[inline]
pub fn rn_bf16(x: f32) -> f32 {
    super::format::bf16_round(x)
}

// ---------------------------------------------------------------------------
// Basic algorithms (Appendix C), generic over format.
// ---------------------------------------------------------------------------

/// TwoSum (Alg. 2): exact `a + b = x + y` for *any* ordering of a, b.
pub fn two_sum(fmt: &FloatFormat, a: f32, b: f32) -> (f32, f32) {
    let x = rn(fmt, a as f64 + b as f64);
    let b_virtual = rn(fmt, x as f64 - a as f64);
    let a_virtual = rn(fmt, x as f64 - b_virtual as f64);
    let b_roundoff = rn(fmt, b as f64 - b_virtual as f64);
    let a_roundoff = rn(fmt, a as f64 - a_virtual as f64);
    let y = rn(fmt, a_roundoff as f64 + b_roundoff as f64);
    (x, y)
}

/// Fast2Sum (Dekker 1971; Thm 4.1): requires `|a| >= |b|`;
/// exact `a + b = x + y` with `|y| <= ulp(x)/2`.
pub fn fast2sum(fmt: &FloatFormat, a: f32, b: f32) -> (f32, f32) {
    let x = rn(fmt, a as f64 + b as f64);
    let y = rn(fmt, b as f64 - (rn(fmt, x as f64 - a as f64) as f64));
    (x, y)
}

/// TwoProdFMA (Alg. 5): exact `a * b = x + e`.  The f64 product of two
/// p ≤ 11-bit-significand values is exact, so the error term is computed
/// exactly (see DESIGN.md §TwoProdFMA note).
pub fn two_prod(fmt: &FloatFormat, a: f32, b: f32) -> (f32, f32) {
    let prod = a as f64 * b as f64; // exact for p<=26 operands
    let x = rn(fmt, prod);
    let e = rn(fmt, prod - x as f64);
    (x, e)
}

/// Split (Alg. 3): `a = a_hi + a_lo`, each with ~p/2 mantissa bits.
/// Provided for completeness (TwoProd uses the FMA realization instead).
pub fn split(fmt: &FloatFormat, a: f32) -> (f32, f32) {
    let c = fmt.mantissa_bits.div_ceil(2);
    let factor = (1u64 << c) as f64 + 1.0;
    let t = rn(fmt, factor * a as f64);
    let a_hi = rn(fmt, t as f64 - rn(fmt, t as f64 - a as f64) as f64);
    let a_lo = rn(fmt, a as f64 - a_hi as f64);
    (a_hi, a_lo)
}

/// Grow (Alg. 1): add float `a` to expansion `(x, y)`, assuming `|x| >= |a|`.
pub fn grow(fmt: &FloatFormat, e: Expansion, a: f32) -> Expansion {
    let (u, v) = fast2sum(fmt, e.hi, a);
    let (u, v) = fast2sum(fmt, u, rn(fmt, e.lo as f64 + v as f64));
    Expansion { hi: u, lo: v }
}

/// Scaling (Alg. 6): expansion × float.
pub fn scaling(fmt: &FloatFormat, a: Expansion, v: f32) -> Expansion {
    let (x, e) = two_prod(fmt, a.hi, v);
    let e = rn(fmt, rn(fmt, a.lo as f64 * v as f64) as f64 + e as f64);
    let (x, e) = fast2sum(fmt, x, e);
    Expansion { hi: x, lo: e }
}

/// Mul (Alg. 7): expansion × expansion.
pub fn mul(fmt: &FloatFormat, a: Expansion, b: Expansion) -> Expansion {
    let (x, e) = two_prod(fmt, a.hi, b.hi);
    let cross = rn(
        fmt,
        rn(fmt, a.hi as f64 * b.lo as f64) as f64 + rn(fmt, a.lo as f64 * b.hi as f64) as f64,
    );
    let e = rn(fmt, e as f64 + cross as f64);
    let (x, e) = fast2sum(fmt, x, e);
    Expansion { hi: x, lo: e }
}

// ---------------------------------------------------------------------------
// Length-N expansions (N ∈ {2, 3}) — the §6 extension lever.
//
// A length-2 expansion buys ≈ one extra word of precision; at 8 bits that
// is not enough (the δθ word's own ulp swamps the update once |δθ| grows —
// see `optim::generic`'s fp8 stall test).  `ExpansionN` generalizes the
// pair algebra to N ordered, (weakly) non-overlapping components with
// Priest-style renormalization: a bottom-up Fast2Sum accumulation pass
// followed by an error-combine pass (`TwoSum`, valid for any ordering).
// For N = 2 every algorithm below performs the *identical* op sequence as
// its pair counterpart (`grow`/`scaling`/`mul`), so the two algebras are
// bitwise interchangeable — `tests/expansion_n.rs` enforces it.
// ---------------------------------------------------------------------------

/// A length-`N` expansion: the unevaluated sum `c[0] + c[1] + ... + c[N-1]`
/// with components ordered by decreasing magnitude.  Adjacent components
/// are weakly non-overlapping after [`renormalize`]: `|c[i+1]| ≤ ulp(c[i])`
/// (the double-double convention; strict `ulp/2` non-overlap holds for the
/// bottom pair).  Saturating formats (E4M3) break the bound only when
/// `c[0]` is pinned at `±max_finite`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExpansionN<const N: usize> {
    pub c: [f32; N],
}

impl<const N: usize> ExpansionN<N> {
    pub fn new(c: [f32; N]) -> Self {
        ExpansionN { c }
    }

    pub fn zero() -> Self {
        ExpansionN { c: [0.0; N] }
    }

    /// The evaluated value — the exact unevaluated sum, in f64 (exact for
    /// every format here as long as component exponents span < 53 binades,
    /// which non-overlapping components of a ≤ 11-bit format always do).
    pub fn value(&self) -> f64 {
        let mut s = 0.0f64;
        for &x in &self.c {
            s += x as f64;
        }
        s
    }

    /// Exact length-N expansion of an f64 scalar in `fmt` — the Table 1
    /// construction iterated: `c[i] = RN(x − Σ_{j<i} c[j])`.
    /// For N = 2 this is exactly [`Expansion::split_scalar`].
    pub fn split_scalar(fmt: &FloatFormat, x: f64) -> Self {
        let mut c = [0.0f32; N];
        let mut rem = x;
        for ci in c.iter_mut() {
            *ci = fmt.round_nearest_f64(rem);
            rem -= *ci as f64;
        }
        ExpansionN { c }
    }
}

impl From<Expansion> for ExpansionN<2> {
    fn from(e: Expansion) -> Self {
        ExpansionN { c: [e.hi, e.lo] }
    }
}

impl From<ExpansionN<2>> for Expansion {
    fn from(e: ExpansionN<2>) -> Self {
        Expansion { hi: e.c[0], lo: e.c[1] }
    }
}

/// Priest-style renormalization of `N` roughly-ordered terms into a
/// (weakly) non-overlapping expansion: a bottom-up Fast2Sum accumulation
/// (leading term + per-level errors), then the errors combined with
/// [`two_sum`] (valid for any ordering, unlike Fast2Sum).  For N = 2 this
/// is exactly one `fast2sum(t[0], t[1])` — the pair-algebra op.
///
/// One pass compacts fully when the leading term dominates; under
/// catastrophic cancellation (`t[0] + t[1]` collapsing far below `t[0]`)
/// the exact sum is still preserved but adjacent components may overlap by
/// a bit until a later grow re-compacts them — the same single-pass
/// behavior the pair algebra has always had.
pub fn renormalize<const N: usize>(fmt: &FloatFormat, t: [f32; N]) -> ExpansionN<N> {
    assert!(N >= 2, "expansions have at least two components");
    let mut e = [0.0f32; N];
    let mut s = t[N - 1];
    for i in (0..N - 1).rev() {
        let (x, y) = fast2sum(fmt, t[i], s);
        s = x;
        e[i + 1] = y;
    }
    // Error-combine chain over e[1..]: TwoSum pairs cascading down.  For
    // N = 2 this is the identity on e[1]; for N = 3 one two_sum.
    let mut out = [0.0f32; N];
    out[0] = s;
    let mut carry = e[1];
    for i in 2..N {
        let (x, y) = two_sum(fmt, carry, e[i]);
        out[i - 1] = x;
        carry = y;
    }
    out[N - 1] = carry;
    ExpansionN { c: out }
}

/// Grow (Alg. 1 generalized): add float `a` to a length-N expansion,
/// assuming `|e.c[0]| >= |a|`.  The increment cascades down through a
/// Fast2Sum chain (each level absorbs the previous level's error), the
/// bottom component takes the final carry with one rounded add, and the
/// result is renormalized.  For N = 2 this performs exactly the op
/// sequence of [`grow`].
pub fn grow_n<const N: usize>(
    fmt: &FloatFormat,
    e: ExpansionN<N>,
    a: f32,
) -> ExpansionN<N> {
    let mut t = [0.0f32; N];
    let mut carry = a;
    for i in 0..N - 1 {
        let (x, y) = fast2sum(fmt, e.c[i], carry);
        t[i] = x;
        carry = y;
    }
    t[N - 1] = rn(fmt, e.c[N - 1] as f64 + carry as f64);
    renormalize(fmt, t)
}

/// Scaling (Alg. 6 generalized): length-N expansion × float.  Each
/// component contributes its exact product (TwoProdFMA); the product error
/// of level `i` is absorbed into level `i + 1`; the bottom component keeps
/// only its rounded product.  For N = 2: exactly [`scaling`].
pub fn scaling_n<const N: usize>(
    fmt: &FloatFormat,
    a: ExpansionN<N>,
    v: f32,
) -> ExpansionN<N> {
    let mut t = [0.0f32; N];
    let (x, mut err) = two_prod(fmt, a.c[0], v);
    t[0] = x;
    for i in 1..N {
        if i < N - 1 {
            let (p, pe) = two_prod(fmt, a.c[i], v);
            t[i] = rn(fmt, p as f64 + err as f64);
            err = pe;
        } else {
            t[i] = rn(fmt, rn(fmt, a.c[i] as f64 * v as f64) as f64 + err as f64);
        }
    }
    renormalize(fmt, t)
}

/// Mul (Alg. 7 generalized): length-N × length-N expansion.  Order-k terms
/// (`Σ_{i+j=k} aᵢ·bⱼ`) land in component k; the head product's error term
/// is absorbed into component 1; higher-order cross-product errors are
/// dropped (the same truncation the pair algebra applies).  For N = 2:
/// exactly [`mul`].
pub fn mul_n<const N: usize>(
    fmt: &FloatFormat,
    a: ExpansionN<N>,
    b: ExpansionN<N>,
) -> ExpansionN<N> {
    let mut t = [0.0f32; N];
    let (x, e00) = two_prod(fmt, a.c[0], b.c[0]);
    t[0] = x;
    for k in 1..N {
        let mut s = rn(fmt, a.c[0] as f64 * b.c[k] as f64);
        for i in 1..=k {
            s = rn(
                fmt,
                s as f64 + rn(fmt, a.c[i] as f64 * b.c[k - i] as f64) as f64,
            );
        }
        // Only component 1 absorbs the head product's error term.
        t[k] = if k == 1 { rn(fmt, e00 as f64 + s as f64) } else { s };
    }
    renormalize(fmt, t)
}

// ---------------------------------------------------------------------------
// 8-wide lane variants.
//
// The Fast2Sum dependency chain inside ONE element cannot vectorize — every
// op consumes the previous op's rounded result.  Across elements there are
// no dependencies at all, so the lane variants below process 8 independent
// elements per chain step: each scalar `RN(a ∘ b)` becomes one
// `FloatFormat::round_nearest_f64_x8` over 8 lanes, in the *identical* op
// order as the scalar function.  Because every op is pure and per-element,
// each lane's result is bitwise equal to the scalar call on that lane's
// inputs — `prop_lane_ops_match_scalar` below pins it, and the optimizer
// lane kernels (`optim/kernels.rs`) inherit the guarantee.
// ---------------------------------------------------------------------------

/// Lane width shared by the x8 algebra and `FloatFormat::round_x8`.
pub const LANES: usize = 8;

#[inline]
fn rn_x8(fmt: &FloatFormat, x: [f64; LANES]) -> [f32; LANES] {
    fmt.round_nearest_f64_x8(x)
}

/// [`two_sum`] over 8 independent lanes (identical op sequence per lane).
pub fn two_sum_x8(fmt: &FloatFormat, a: [f32; LANES], b: [f32; LANES]) -> ([f32; LANES], [f32; LANES]) {
    use std::array::from_fn;
    let x = rn_x8(fmt, from_fn(|l| a[l] as f64 + b[l] as f64));
    let b_virtual = rn_x8(fmt, from_fn(|l| x[l] as f64 - a[l] as f64));
    let a_virtual = rn_x8(fmt, from_fn(|l| x[l] as f64 - b_virtual[l] as f64));
    let b_roundoff = rn_x8(fmt, from_fn(|l| b[l] as f64 - b_virtual[l] as f64));
    let a_roundoff = rn_x8(fmt, from_fn(|l| a[l] as f64 - a_virtual[l] as f64));
    let y = rn_x8(fmt, from_fn(|l| a_roundoff[l] as f64 + b_roundoff[l] as f64));
    (x, y)
}

/// [`fast2sum`] over 8 independent lanes.
pub fn fast2sum_x8(fmt: &FloatFormat, a: [f32; LANES], b: [f32; LANES]) -> ([f32; LANES], [f32; LANES]) {
    use std::array::from_fn;
    let x = rn_x8(fmt, from_fn(|l| a[l] as f64 + b[l] as f64));
    let t = rn_x8(fmt, from_fn(|l| x[l] as f64 - a[l] as f64));
    let y = rn_x8(fmt, from_fn(|l| b[l] as f64 - t[l] as f64));
    (x, y)
}

/// [`two_prod`] over 8 independent lanes.
pub fn two_prod_x8(fmt: &FloatFormat, a: [f32; LANES], b: [f32; LANES]) -> ([f32; LANES], [f32; LANES]) {
    use std::array::from_fn;
    let prod: [f64; LANES] = from_fn(|l| a[l] as f64 * b[l] as f64); // exact for p<=26 operands
    let x = rn_x8(fmt, prod);
    let e = rn_x8(fmt, from_fn(|l| prod[l] - x[l] as f64));
    (x, e)
}

/// [`grow`] over 8 independent lanes: add `a[l]` to expansion
/// `(hi[l], lo[l])` per lane.  Returns the new `(hi, lo)` lanes.
pub fn grow_x8(
    fmt: &FloatFormat,
    hi: [f32; LANES],
    lo: [f32; LANES],
    a: [f32; LANES],
) -> ([f32; LANES], [f32; LANES]) {
    use std::array::from_fn;
    let (u, v) = fast2sum_x8(fmt, hi, a);
    let w = rn_x8(fmt, from_fn(|l| lo[l] as f64 + v[l] as f64));
    fast2sum_x8(fmt, u, w)
}

/// [`mul`] over 8 independent lanes: expansion × expansion per lane.
pub fn mul_x8(
    fmt: &FloatFormat,
    a_hi: [f32; LANES],
    a_lo: [f32; LANES],
    b_hi: [f32; LANES],
    b_lo: [f32; LANES],
) -> ([f32; LANES], [f32; LANES]) {
    use std::array::from_fn;
    let (x, e) = two_prod_x8(fmt, a_hi, b_hi);
    let c1 = rn_x8(fmt, from_fn(|l| a_hi[l] as f64 * b_lo[l] as f64));
    let c2 = rn_x8(fmt, from_fn(|l| a_lo[l] as f64 * b_hi[l] as f64));
    let cross = rn_x8(fmt, from_fn(|l| c1[l] as f64 + c2[l] as f64));
    let e = rn_x8(fmt, from_fn(|l| e[l] as f64 + cross[l] as f64));
    fast2sum_x8(fmt, x, e)
}

/// [`renormalize`] over 8 independent lanes (component-major layout:
/// `t[i][l]` is component `i` of lane `l`).
pub fn renormalize_x8<const N: usize>(fmt: &FloatFormat, t: [[f32; LANES]; N]) -> [[f32; LANES]; N] {
    assert!(N >= 2, "expansions have at least two components");
    let mut e = [[0.0f32; LANES]; N];
    let mut s = t[N - 1];
    for i in (0..N - 1).rev() {
        let (x, y) = fast2sum_x8(fmt, t[i], s);
        s = x;
        e[i + 1] = y;
    }
    let mut out = [[0.0f32; LANES]; N];
    out[0] = s;
    let mut carry = e[1];
    for i in 2..N {
        let (x, y) = two_sum_x8(fmt, carry, e[i]);
        out[i - 1] = x;
        carry = y;
    }
    out[N - 1] = carry;
    out
}

/// [`grow_n`] over 8 independent lanes.
pub fn grow_n_x8<const N: usize>(
    fmt: &FloatFormat,
    c: [[f32; LANES]; N],
    a: [f32; LANES],
) -> [[f32; LANES]; N] {
    use std::array::from_fn;
    let mut t = [[0.0f32; LANES]; N];
    let mut carry = a;
    for i in 0..N - 1 {
        let (x, y) = fast2sum_x8(fmt, c[i], carry);
        t[i] = x;
        carry = y;
    }
    t[N - 1] = rn_x8(fmt, from_fn(|l| c[N - 1][l] as f64 + carry[l] as f64));
    renormalize_x8(fmt, t)
}

/// [`mul_n`] over 8 independent lanes.
pub fn mul_n_x8<const N: usize>(
    fmt: &FloatFormat,
    a: [[f32; LANES]; N],
    b: [[f32; LANES]; N],
) -> [[f32; LANES]; N] {
    use std::array::from_fn;
    let mut t = [[0.0f32; LANES]; N];
    let (x, e00) = two_prod_x8(fmt, a[0], b[0]);
    t[0] = x;
    for k in 1..N {
        let mut s = rn_x8(fmt, from_fn(|l| a[0][l] as f64 * b[k][l] as f64));
        for i in 1..=k {
            let p = rn_x8(fmt, from_fn(|l| a[i][l] as f64 * b[k - i][l] as f64));
            s = rn_x8(fmt, from_fn(|l| s[l] as f64 + p[l] as f64));
        }
        t[k] = if k == 1 {
            rn_x8(fmt, from_fn(|l| e00[l] as f64 + s[l] as f64))
        } else {
            s
        };
    }
    renormalize_x8(fmt, t)
}

// ---------------------------------------------------------------------------
// bf16 fast paths (f32 arithmetic + bit-trick rounding).  These are the
// exact same functions specialized for the optimizer hot loop; tests assert
// bitwise agreement with the generic versions.
// ---------------------------------------------------------------------------

/// Fast2Sum in bf16 via f32 intermediates (innocuous double rounding).
#[inline]
pub fn fast2sum_bf16(a: f32, b: f32) -> (f32, f32) {
    let x = rn_bf16(a + b);
    let y = rn_bf16(b - rn_bf16(x - a));
    (x, y)
}

/// Grow in bf16 via f32 intermediates.
#[inline]
pub fn grow_bf16(hi: f32, lo: f32, a: f32) -> (f32, f32) {
    let (u, v) = fast2sum_bf16(hi, a);
    fast2sum_bf16(u, rn_bf16(lo + v))
}

/// TwoProdFMA in bf16: the product of two bf16 values is exact in f32.
#[inline]
pub fn two_prod_bf16(a: f32, b: f32) -> (f32, f32) {
    let prod = a * b; // exact: 8+8 significand bits fit in f32's 24
    let x = rn_bf16(prod);
    let e = rn_bf16(prod - x);
    (x, e)
}

/// Mul in bf16 via f32 intermediates.
#[inline]
pub fn mul_bf16(a_hi: f32, a_lo: f32, b_hi: f32, b_lo: f32) -> (f32, f32) {
    let (x, e) = two_prod_bf16(a_hi, b_hi);
    let cross = rn_bf16(rn_bf16(a_hi * b_lo) + rn_bf16(a_lo * b_hi));
    let e = rn_bf16(e + cross);
    fast2sum_bf16(x, e)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check_msg, gen_bf16_interesting};

    fn gen_pair(rng: &mut crate::util::rng::Rng) -> (f32, f32) {
        (gen_bf16_interesting(rng), gen_bf16_interesting(rng))
    }

    fn gen_sorted_pair(rng: &mut crate::util::rng::Rng) -> (f32, f32) {
        let (a, b) = gen_pair(rng);
        if a.abs() >= b.abs() {
            (a, b)
        } else {
            (b, a)
        }
    }

    #[test]
    fn prop_two_sum_exact() {
        // a + b == x + y exactly (f64 evaluation is exact for bf16 pairs
        // whose exponents span < 45 binades; our generator stays within).
        check_msg("two_sum exact", gen_pair, |&(a, b)| {
            if !(a + b).is_finite() {
                return Ok(());
            }
            let (x, y) = two_sum(&BF16, a, b);
            let lhs = a as f64 + b as f64;
            let rhs = x as f64 + y as f64;
            if lhs == rhs {
                Ok(())
            } else {
                Err(format!("{a:e}+{b:e}: ({x:e},{y:e}) sums to {rhs:e} != {lhs:e}"))
            }
        });
    }

    #[test]
    fn prop_fast2sum_exact_and_bounded() {
        check_msg("fast2sum exact", gen_sorted_pair, |&(a, b)| {
            if !(a + b).is_finite() {
                return Ok(());
            }
            let (x, y) = fast2sum(&BF16, a, b);
            if a as f64 + b as f64 != x as f64 + y as f64 {
                return Err(format!("not exact: ({x:e},{y:e})"));
            }
            // Thm 4.1: |y| <= ulp(x)/2
            if x != 0.0 && (y.abs() as f64) > BF16.ulp(x) / 2.0 {
                return Err(format!("|y|={:e} > ulp(x)/2={:e}", y.abs(), BF16.ulp(x) / 2.0));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_fast2sum_matches_two_sum_when_sorted() {
        check_msg("fast2sum == two_sum (sorted)", gen_sorted_pair, |&(a, b)| {
            if !(a + b).is_finite() {
                return Ok(());
            }
            let s1 = fast2sum(&BF16, a, b);
            let s2 = two_sum(&BF16, a, b);
            if s1 == s2 {
                Ok(())
            } else {
                Err(format!("fast {s1:?} != two {s2:?}"))
            }
        });
    }

    #[test]
    fn prop_two_prod_exact() {
        check_msg("two_prod exact", gen_pair, |&(a, b)| {
            let p = a as f64 * b as f64;
            if !p.is_finite() || p != 0.0 && p.abs() < 1e-30 {
                return Ok(()); // underflow region: error term subnormalizes
            }
            let (x, e) = two_prod(&BF16, a, b);
            if !x.is_finite() {
                return Ok(());
            }
            if x as f64 + e as f64 == p {
                Ok(())
            } else {
                Err(format!("{a:e}*{b:e}: {x:e}+{e:e} != {p:e}"))
            }
        });
    }

    #[test]
    fn prop_bf16_fast_paths_match_generic() {
        check_msg("bf16 fast == generic", gen_sorted_pair, |&(a, b)| {
            if !(a + b).is_finite() || !(a * b).is_finite() {
                return Ok(());
            }
            let f = fast2sum_bf16(a, b);
            let g = fast2sum(&BF16, a, b);
            if f != g {
                return Err(format!("fast2sum {f:?} != {g:?}"));
            }
            let p1 = two_prod_bf16(a, b);
            let p2 = two_prod(&BF16, a, b);
            if p1 != p2 && !(p1.0.is_nan() || p2.0.is_nan()) {
                return Err(format!("two_prod {p1:?} != {p2:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn grow_accumulates_small_updates() {
        // The paper's headline micro-behaviour: adding 0.1 to 200 in bf16 is
        // lost under plain ⊕ but preserved by Grow on an expansion.
        let mut plain = 200.0f32;
        let mut exp = Expansion::new(200.0, 0.0);
        let upd = BF16.round_nearest(0.1);
        for _ in 0..64 {
            plain = rn_bf16(plain + upd);
            exp = grow(&BF16, exp, upd);
        }
        assert_eq!(plain, 200.0, "plain bf16 add should be entirely lost");
        let truth = 200.0 + 64.0 * upd as f64;
        assert!(
            (exp.value() - truth).abs() < 0.5,
            "expansion drifted: {} vs {truth}",
            exp.value()
        );
    }

    #[test]
    fn split_scalar_table1() {
        // Paper Table 1 β₂ expansions.
        let e999 = Expansion::split_scalar(&BF16, 0.999);
        assert_eq!(e999.hi, 1.0);
        assert!((e999.lo + 0.001).abs() < 1e-5, "lo={}", e999.lo);
        let e95 = Expansion::split_scalar(&BF16, 0.95);
        assert_eq!(e95.hi, 0.94921875);
        assert!((e95.value() - 0.95).abs() < 1e-6);
        let e99 = Expansion::split_scalar(&BF16, 0.99);
        assert!((e99.value() - 0.99).abs() < 1e-5);
    }

    #[test]
    fn mul_expansion_more_accurate_than_plain() {
        // (β₂ expansion)·(v expansion) vs plain bf16 multiply: the paper's
        // second-moment decay argument.
        let b2 = Expansion::split_scalar(&BF16, 0.999);
        let mut v_plain = 1.0f32;
        let mut v_exp = Expansion::new(1.0, 0.0);
        for _ in 0..200 {
            v_plain = rn_bf16(v_plain * rn_bf16(0.999));
            v_exp = mul(&BF16, v_exp, b2);
        }
        let truth = 0.999f64.powi(200);
        assert_eq!(v_plain, 1.0, "plain bf16: 0.999 rounds to 1.0, no decay");
        assert!(
            (v_exp.value() - truth).abs() / truth < 0.05,
            "expansion decay {} vs {truth}",
            v_exp.value()
        );
    }

    #[test]
    fn prop_grow_preserves_sum_approximately() {
        check_msg(
            "grow error bounded",
            |rng| {
                let hi = gen_bf16_interesting(rng).abs().max(1e-10);
                let lo = BF16.round_nearest(hi * 0.001 * (rng.f32() - 0.5));
                let a = BF16.round_nearest(hi * rng.f32());
                (hi, lo, a)
            },
            |&(hi, lo, a)| {
                let e = grow(&BF16, Expansion::new(hi, lo), a);
                if !e.hi.is_finite() {
                    return Ok(());
                }
                let truth = hi as f64 + lo as f64 + a as f64;
                let err = (e.value() - truth).abs();
                // Grow's only unrecovered rounding is inside F(lo ⊕ v) and
                // the second Fast2Sum's lo word; both are ≤ ulp(hi)/2, so
                // a sound (loose) bound is one ulp of the result's hi word.
                let bound = BF16.ulp(e.hi);
                if err <= bound.max(truth.abs() * 1e-4) {
                    Ok(())
                } else {
                    Err(format!("err {err:e} > bound {bound:e} (truth {truth:e})"))
                }
            },
        );
    }

    #[test]
    fn prop_lane_ops_match_scalar_bitwise() {
        // Every x8 function must be bitwise equal, lane for lane, to 8
        // scalar calls — across formats, including the saturating one.
        use crate::numerics::format::{FP16, FP8E4M3};
        fn gen_lanes(rng: &mut crate::util::rng::Rng) -> ([f32; LANES], [f32; LANES], [f32; LANES]) {
            let mut a = [0.0f32; LANES];
            let mut b = [0.0f32; LANES];
            let mut c = [0.0f32; LANES];
            for l in 0..LANES {
                let (x, y) = {
                    let p = gen_bf16_interesting(rng);
                    let q = gen_bf16_interesting(rng);
                    if p.abs() >= q.abs() { (p, q) } else { (q, p) }
                };
                a[l] = x;
                b[l] = y;
                c[l] = gen_bf16_interesting(rng);
            }
            (a, b, c)
        }
        let eq = |u: f32, v: f32| u.to_bits() == v.to_bits();
        check_msg("lane ops == scalar", gen_lanes, |&(a, b, c)| {
            for fmt in [&BF16, &FP16, &FP8E4M3] {
                let (x8, y8) = two_sum_x8(fmt, a, b);
                let (f8, g8) = fast2sum_x8(fmt, a, b);
                let (p8, e8) = two_prod_x8(fmt, a, b);
                let (gh8, gl8) = grow_x8(fmt, a, b, c);
                let (mh8, ml8) = mul_x8(fmt, a, b, a, b);
                let gn8 = grow_n_x8::<3>(fmt, [a, b, c], c);
                let mn8 = mul_n_x8::<3>(fmt, [a, b, c], [a, b, c]);
                for l in 0..LANES {
                    let (x, y) = two_sum(fmt, a[l], b[l]);
                    let (f, g) = fast2sum(fmt, a[l], b[l]);
                    let (p, e) = two_prod(fmt, a[l], b[l]);
                    let gr = grow(fmt, Expansion::new(a[l], b[l]), c[l]);
                    let mu = mul(fmt, Expansion::new(a[l], b[l]), Expansion::new(a[l], b[l]));
                    let gn = grow_n(fmt, ExpansionN::new([a[l], b[l], c[l]]), c[l]);
                    let mn = mul_n(
                        fmt,
                        ExpansionN::new([a[l], b[l], c[l]]),
                        ExpansionN::new([a[l], b[l], c[l]]),
                    );
                    let ok = eq(x8[l], x)
                        && eq(y8[l], y)
                        && eq(f8[l], f)
                        && eq(g8[l], g)
                        && eq(p8[l], p)
                        && eq(e8[l], e)
                        && eq(gh8[l], gr.hi)
                        && eq(gl8[l], gr.lo)
                        && eq(mh8[l], mu.hi)
                        && eq(ml8[l], mu.lo)
                        && (0..3).all(|i| eq(gn8[i][l], gn.c[i]))
                        && (0..3).all(|i| eq(mn8[i][l], mn.c[i]));
                    if !ok {
                        return Err(format!(
                            "lane {l} diverged for fmt {} on a={:e} b={:e} c={:e}",
                            fmt.name, a[l], b[l], c[l]
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn expansion_components_nonoverlapping() {
        check_msg("nonoverlap |lo| <= ulp(hi)/2", gen_sorted_pair, |&(a, b)| {
            if !(a + b).is_finite() {
                return Ok(());
            }
            let (x, y) = fast2sum(&BF16, a, b);
            if x == 0.0 || y == 0.0 {
                return Ok(());
            }
            if (y.abs() as f64) <= BF16.ulp(x) / 2.0 {
                Ok(())
            } else {
                Err(format!("overlap: x={x:e} y={y:e} ulp(x)={:e}", BF16.ulp(x)))
            }
        });
    }
}
