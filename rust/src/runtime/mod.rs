//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the only module that touches the `xla` FFI crate; everything
//! above it deals in plain `f32`/`i32` host vectors.

pub mod artifact;
pub mod client;
pub mod executable;

pub use artifact::{ArtifactKind, ArtifactMeta, IoSpec, Manifest, ModelMeta, ParamEntry};
pub use client::Runtime;
pub use executable::{Executable, Input, InputRef};
