//! AOT artifact manifest: the contract between `python/compile/aot.py`
//! (which writes `artifacts/manifest.json`) and the Rust coordinator.
//!
//! The manifest pins, per artifact: the HLO file, the model config, the
//! precision option, the ordered input/output tensor specs, the optimizer
//! state layout and a content hash.  The runtime refuses to execute an
//! artifact whose on-disk HLO no longer matches its recorded hash.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Value;

/// What a lowered computation does.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// Full fused train step: fwd + bwd + optimizer update + metrics.
    Train,
    /// Validation loss only.
    Eval,
    /// Forward + backward only (data-parallel workers).
    Grad,
    /// Final-position argmax (classification accuracy for Table 4).
    Predict,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "train" => Self::Train,
            "eval" => Self::Eval,
            "grad" => Self::Grad,
            "predict" => Self::Predict,
            other => bail!("unknown artifact kind {other:?}"),
        })
    }
}

/// Dtype/shape of one executable input or output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IoSpec {
    pub name: String,
    pub dtype: String, // "f32" | "s32" | "u32"
    pub shape: Vec<usize>,
}

impl IoSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(v: &Value) -> Result<Self> {
        Ok(IoSpec {
            name: v.get("name")?.as_str()?.to_string(),
            dtype: v.get("dtype")?.as_str()?.to_string(),
            shape: v
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|x| Ok(x.as_usize()?))
                .collect::<Result<_>>()?,
        })
    }
}

/// One row of the flat-parameter layout table.
#[derive(Debug, Clone)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

impl ParamEntry {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Model architecture + geometry, mirrored from `model.ModelConfig`.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub seq_len: usize,
    pub micro_batch: usize,
    pub n_params: usize,
    pub padded_len: usize,
    pub param_table: Vec<ParamEntry>,
    pub init_file: Option<String>,
}

/// AdamW hyper-parameters baked into a config's train artifacts.
#[derive(Debug, Clone, Copy)]
pub struct OptimMeta {
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub weight_decay: f64,
    pub grad_clip: f64,
}

/// One lowered computation.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub file: String,
    pub kind: ArtifactKind,
    pub config: String,
    /// Precision option for train artifacts (`a`, `collage-light`, ...).
    pub option: Option<String>,
    /// β₂ override for ablation artifacts (None = the config default).
    pub beta2: Option<f64>,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    /// Optimizer state vector names, in I/O order (train artifacts).
    pub state: Vec<String>,
    pub sha256: String,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub block: usize,
    pub metric_names: Vec<String>,
    pub options: Vec<String>,
    pub configs: BTreeMap<String, ModelMeta>,
    pub optim: BTreeMap<String, OptimMeta>,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let v = Value::parse(&text).context("parsing manifest.json")?;

        let mut configs = BTreeMap::new();
        for (name, c) in v.get("configs")?.as_obj()?.iter() {
            let mut param_table = Vec::new();
            for row in c.get("param_table")?.as_arr()? {
                param_table.push(ParamEntry {
                    name: row.get("name")?.as_str()?.to_string(),
                    shape: row
                        .get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|x| Ok(x.as_usize()?))
                        .collect::<Result<_>>()?,
                    offset: row.get("offset")?.as_usize()?,
                });
            }
            configs.insert(
                name.clone(),
                ModelMeta {
                    name: name.clone(),
                    vocab: c.get("vocab")?.as_usize()?,
                    d_model: c.get("d_model")?.as_usize()?,
                    n_layers: c.get("n_layers")?.as_usize()?,
                    n_heads: c.get("n_heads")?.as_usize()?,
                    seq_len: c.get("seq_len")?.as_usize()?,
                    micro_batch: c.get("micro_batch")?.as_usize()?,
                    n_params: c.get("n_params")?.as_usize()?,
                    padded_len: c.get("padded_len")?.as_usize()?,
                    param_table,
                    init_file: c.opt("init_file").map(|f| f.as_str().unwrap_or("").to_string()),
                },
            );
        }

        let mut optim = BTreeMap::new();
        if let Ok(o) = v.get("optim") {
            for (name, m) in o.as_obj()?.iter() {
                optim.insert(
                    name.clone(),
                    OptimMeta {
                        beta1: m.get("beta1")?.as_f64()?,
                        beta2: m.get("beta2")?.as_f64()?,
                        eps: m.get("eps")?.as_f64()?,
                        weight_decay: m.get("weight_decay")?.as_f64()?,
                        grad_clip: m.get("grad_clip")?.as_f64()?,
                    },
                );
            }
        }

        let mut artifacts = Vec::new();
        for a in v.get("artifacts")?.as_arr()? {
            let state = match a.opt("state") {
                Some(rows) => rows
                    .as_arr()?
                    .iter()
                    .map(|r| Ok(r.get("name")?.as_str()?.to_string()))
                    .collect::<Result<_>>()?,
                None => Vec::new(),
            };
            artifacts.push(ArtifactMeta {
                file: a.get("file")?.as_str()?.to_string(),
                kind: ArtifactKind::parse(a.get("kind")?.as_str()?)?,
                config: a.get("config")?.as_str()?.to_string(),
                option: a.opt("option").map(|o| o.as_str().unwrap_or("").to_string()),
                beta2: a.opt("beta2").map(|b| b.as_f64().unwrap_or(f64::NAN)),
                inputs: a
                    .get("inputs")?
                    .as_arr()?
                    .iter()
                    .map(IoSpec::parse)
                    .collect::<Result<_>>()?,
                outputs: a
                    .get("outputs")?
                    .as_arr()?
                    .iter()
                    .map(IoSpec::parse)
                    .collect::<Result<_>>()?,
                state,
                sha256: a.get("sha256")?.as_str()?.to_string(),
            });
        }

        let metric_names = v
            .get("metric_names")?
            .as_arr()?
            .iter()
            .map(|m| Ok(m.as_str()?.to_string()))
            .collect::<Result<_>>()?;
        let options = v
            .get("options")?
            .as_arr()?
            .iter()
            .map(|m| Ok(m.as_str()?.to_string()))
            .collect::<Result<_>>()?;

        Ok(Manifest {
            dir,
            block: v.get("block")?.as_usize()?,
            metric_names,
            options,
            configs,
            optim,
            artifacts,
        })
    }

    /// Find the train artifact for (config, option, β₂-override).
    pub fn train(&self, config: &str, option: &str, beta2: Option<f64>) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| {
                a.kind == ArtifactKind::Train
                    && a.config == config
                    && a.option.as_deref() == Some(option)
                    && match beta2 {
                        None => a.beta2.is_none(),
                        Some(b) => a.beta2.map(|x| (x - b).abs() < 1e-9).unwrap_or(false),
                    }
            })
            .with_context(|| {
                format!("no train artifact for config={config} option={option} beta2={beta2:?}")
            })
    }

    /// Find the eval (or grad) artifact for a config.
    pub fn find(&self, config: &str, kind: ArtifactKind) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.kind == kind && a.config == config)
            .with_context(|| format!("no {kind:?} artifact for config={config}"))
    }

    pub fn model(&self, config: &str) -> Result<&ModelMeta> {
        self.configs
            .get(config)
            .with_context(|| format!("config {config:?} not in manifest"))
    }

    pub fn optim(&self, config: &str) -> Result<&OptimMeta> {
        self.optim
            .get(config)
            .with_context(|| format!("optim hyper-params for {config:?} not in manifest"))
    }

    /// Absolute path of an artifact file.
    pub fn path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }

    /// Load the exported initial flat parameter vector for a config.
    pub fn load_init(&self, config: &str) -> Result<Vec<f32>> {
        let model = self.model(config)?;
        let file = model
            .init_file
            .as_ref()
            .with_context(|| format!("config {config} has no init file"))?;
        read_npy_f32(&self.dir.join(file))
    }
}

/// Minimal NPY (v1.0) reader for little-endian f32 1-D arrays — the format
/// `aot.py` uses for the initial parameter vector.
pub fn read_npy_f32(path: &Path) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    if bytes.len() < 10 || &bytes[..6] != b"\x93NUMPY" {
        bail!("{path:?} is not an NPY file");
    }
    let header_len = u16::from_le_bytes([bytes[8], bytes[9]]) as usize;
    let header = std::str::from_utf8(&bytes[10..10 + header_len])?;
    if !header.contains("'descr': '<f4'") {
        bail!("NPY {path:?}: expected little-endian f32, got header {header}");
    }
    if header.contains("'fortran_order': True") {
        bail!("NPY {path:?}: fortran order not supported");
    }
    let data = &bytes[10 + header_len..];
    if data.len() % 4 != 0 {
        bail!("NPY {path:?}: data not a multiple of 4 bytes");
    }
    Ok(data
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// SHA-256 of a byte slice (pure-Rust, used to validate artifact hashes).
pub fn sha256_hex(data: &[u8]) -> String {
    // FIPS 180-4 constants
    const K: [u32; 64] = [
        0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4,
        0xab1c5ed5, 0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe,
        0x9bdc06a7, 0xc19bf174, 0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f,
        0x4a7484aa, 0x5cb0a9dc, 0x76f988da, 0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7,
        0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967, 0x27b70a85, 0x2e1b2138, 0x4d2c6dfc,
        0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85, 0xa2bfe8a1, 0xa81a664b,
        0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070, 0x19a4c116,
        0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
        0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7,
        0xc67178f2,
    ];
    let mut h: [u32; 8] = [
        0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab,
        0x5be0cd19,
    ];
    let bit_len = (data.len() as u64).wrapping_mul(8);
    let mut msg = data.to_vec();
    msg.push(0x80);
    while msg.len() % 64 != 56 {
        msg.push(0);
    }
    msg.extend_from_slice(&bit_len.to_be_bytes());
    let mut w = [0u32; 64];
    for chunk in msg.chunks_exact(64) {
        for i in 0..16 {
            w[i] = u32::from_be_bytes([
                chunk[4 * i],
                chunk[4 * i + 1],
                chunk[4 * i + 2],
                chunk[4 * i + 3],
            ]);
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let (mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut hh) =
            (h[0], h[1], h[2], h[3], h[4], h[5], h[6], h[7]);
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = hh
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            hh = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        h[0] = h[0].wrapping_add(a);
        h[1] = h[1].wrapping_add(b);
        h[2] = h[2].wrapping_add(c);
        h[3] = h[3].wrapping_add(d);
        h[4] = h[4].wrapping_add(e);
        h[5] = h[5].wrapping_add(f);
        h[6] = h[6].wrapping_add(g);
        h[7] = h[7].wrapping_add(hh);
    }
    h.iter().map(|x| format!("{x:08x}")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sha256_known_vectors() {
        assert_eq!(
            sha256_hex(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        // multi-block message
        assert_eq!(
            sha256_hex(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn artifact_kind_parse() {
        assert!(ArtifactKind::parse("train").is_ok());
        assert!(ArtifactKind::parse("bogus").is_err());
    }
}
