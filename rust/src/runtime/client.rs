//! PJRT client wrapper: one process-wide CPU client, a compile cache, and
//! artifact integrity checks.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::artifact::{sha256_hex, ArtifactMeta, Manifest};
use super::executable::Executable;

/// Process-wide PJRT runtime.
///
/// Compilation is cached by artifact file name, so repeated
/// `Trainer`/worker construction reuses executables.  `xla::PjRtClient` is
/// internally reference-counted and the underlying CPU client is
/// thread-safe; the cache mutex only guards the map itself.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
    /// Verify on-disk HLO hashes against the manifest before compiling.
    pub verify_hashes: bool,
}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Arc<Self>> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Arc::new(Runtime {
            client,
            cache: Mutex::new(HashMap::new()),
            verify_hashes: true,
        }))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Load + compile one artifact (cached).
    pub fn load(&self, manifest: &Manifest, meta: &ArtifactMeta) -> Result<Arc<Executable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(&meta.file) {
            return Ok(exe.clone());
        }
        let path = manifest.path(meta);
        if self.verify_hashes {
            let text = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
            let got = sha256_hex(&text);
            if got != meta.sha256 {
                bail!(
                    "artifact {:?} hash mismatch (manifest {}, file {}): \
                     re-run `make artifacts`",
                    meta.file,
                    &meta.sha256[..12],
                    &got[..12]
                );
            }
        }
        let exe = Arc::new(self.compile_file(&path, meta.clone())?);
        self.cache
            .lock()
            .unwrap()
            .insert(meta.file.clone(), exe.clone());
        Ok(exe)
    }

    /// Compile an HLO-text file into an executable (uncached).
    pub fn compile_file(&self, path: &Path, meta: ArtifactMeta) -> Result<Executable> {
        let t0 = Instant::now();
        let path_str = path
            .to_str()
            .with_context(|| format!("non-utf8 path {path:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA compile of {path:?}"))?;
        let dt = t0.elapsed();
        Ok(Executable::new(exe, meta, dt))
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("platform", &self.platform())
            .field("devices", &self.device_count())
            .finish()
    }
}
