//! A compiled AOT artifact plus typed host-side I/O.
//!
//! The lowered computations all return a tuple (`return_tuple=True` at
//! lowering); outputs are fetched as one tuple literal and split.  Inputs
//! are staged through device buffers (`execute_b`) so repeated executions
//! can reuse unchanged inputs (see [`Executable::execute_buffers`]).

use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::artifact::ArtifactMeta;

/// One host-side input tensor.
#[derive(Debug, Clone)]
pub enum Input {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
    U32(Vec<u32>, Vec<usize>),
    ScalarF32(f32),
    ScalarU32(u32),
}

impl Input {
    pub fn dtype(&self) -> &'static str {
        match self {
            Input::F32(..) | Input::ScalarF32(_) => "f32",
            Input::I32(..) => "s32",
            Input::U32(..) | Input::ScalarU32(_) => "u32",
        }
    }

    fn shape(&self) -> Vec<usize> {
        match self {
            Input::F32(_, s) | Input::I32(_, s) | Input::U32(_, s) => s.clone(),
            Input::ScalarF32(_) | Input::ScalarU32(_) => vec![],
        }
    }

    pub fn as_ref(&self) -> InputRef<'_> {
        match self {
            Input::F32(d, s) => InputRef::F32(d, s),
            Input::I32(d, s) => InputRef::I32(d, s),
            Input::U32(d, s) => InputRef::U32(d, s),
            Input::ScalarF32(v) => InputRef::ScalarF32(*v),
            Input::ScalarU32(v) => InputRef::ScalarU32(*v),
        }
    }
}

/// Borrowed input tensor — the zero-copy hot-path variant of [`Input`]
/// (§Perf: the trainer's state vectors are uploaded straight from its own
/// buffers instead of being cloned every step).
#[derive(Debug, Clone, Copy)]
pub enum InputRef<'a> {
    F32(&'a [f32], &'a [usize]),
    I32(&'a [i32], &'a [usize]),
    U32(&'a [u32], &'a [usize]),
    ScalarF32(f32),
    ScalarU32(u32),
}

impl<'a> InputRef<'a> {
    pub fn dtype(&self) -> &'static str {
        match self {
            InputRef::F32(..) | InputRef::ScalarF32(_) => "f32",
            InputRef::I32(..) => "s32",
            InputRef::U32(..) | InputRef::ScalarU32(_) => "u32",
        }
    }

    fn shape(&self) -> &[usize] {
        match self {
            InputRef::F32(_, s) | InputRef::I32(_, s) | InputRef::U32(_, s) => s,
            InputRef::ScalarF32(_) | InputRef::ScalarU32(_) => &[],
        }
    }
}

/// Cumulative execution statistics for one executable.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecStats {
    pub executions: u64,
    pub exec_time: Duration,
    pub upload_time: Duration,
    pub download_time: Duration,
}

/// A compiled artifact bound to its manifest metadata.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub meta: ArtifactMeta,
    pub compile_time: Duration,
    stats: std::sync::Mutex<ExecStats>,
}

impl Executable {
    pub(crate) fn new(
        exe: xla::PjRtLoadedExecutable,
        meta: ArtifactMeta,
        compile_time: Duration,
    ) -> Self {
        Executable {
            exe,
            meta,
            compile_time,
            stats: std::sync::Mutex::new(ExecStats::default()),
        }
    }

    pub fn stats(&self) -> ExecStats {
        *self.stats.lock().unwrap()
    }

    /// Upload one input to a device buffer.
    pub fn upload(&self, input: &Input) -> Result<xla::PjRtBuffer> {
        let client = self.exe.client();
        let buf = match input {
            Input::F32(data, shape) => client.buffer_from_host_buffer(data, shape, None)?,
            Input::I32(data, shape) => client.buffer_from_host_buffer(data, shape, None)?,
            Input::U32(data, shape) => client.buffer_from_host_buffer(data, shape, None)?,
            Input::ScalarF32(v) => client.buffer_from_host_buffer(&[*v], &[], None)?,
            Input::ScalarU32(v) => client.buffer_from_host_buffer(&[*v], &[], None)?,
        };
        Ok(buf)
    }

    /// Validate inputs against the manifest spec (shape + dtype).
    fn check_inputs(&self, inputs: &[Input]) -> Result<()> {
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.meta.file,
                self.meta.inputs.len(),
                inputs.len()
            );
        }
        for (got, spec) in inputs.iter().zip(&self.meta.inputs) {
            if got.dtype() != spec.dtype {
                bail!(
                    "{}: input {:?} dtype mismatch: manifest {}, got {}",
                    self.meta.file,
                    spec.name,
                    spec.dtype,
                    got.dtype()
                );
            }
            if got.shape() != spec.shape {
                bail!(
                    "{}: input {:?} shape mismatch: manifest {:?}, got {:?}",
                    self.meta.file,
                    spec.name,
                    spec.shape,
                    got.shape()
                );
            }
        }
        Ok(())
    }

    /// Execute with host inputs; returns one `Vec<f32>` per output
    /// (scalars come back as length-1 vectors).
    pub fn execute(&self, inputs: &[Input]) -> Result<Vec<Vec<f32>>> {
        self.check_inputs(inputs)?;
        let refs: Vec<InputRef> = inputs.iter().map(|i| i.as_ref()).collect();
        self.execute_unchecked(&refs)
    }

    /// Zero-copy execute with borrowed inputs (shape/dtype validated).
    pub fn execute_refs(&self, inputs: &[InputRef]) -> Result<Vec<Vec<f32>>> {
        self.check_input_refs(inputs)?;
        self.execute_unchecked(inputs)
    }

    /// Hot-path execute: borrowed inputs, NO validation (the caller has
    /// validated the layout once — e.g. the trainer at construction).
    pub fn execute_unchecked(&self, inputs: &[InputRef]) -> Result<Vec<Vec<f32>>> {
        let t0 = Instant::now();
        let client = self.exe.client();
        let buffers = inputs
            .iter()
            .map(|i| -> Result<xla::PjRtBuffer> {
                Ok(match i {
                    InputRef::F32(d, s) => client.buffer_from_host_buffer(d, s, None)?,
                    InputRef::I32(d, s) => client.buffer_from_host_buffer(d, s, None)?,
                    InputRef::U32(d, s) => client.buffer_from_host_buffer(d, s, None)?,
                    InputRef::ScalarF32(v) => client.buffer_from_host_buffer(&[*v], &[], None)?,
                    InputRef::ScalarU32(v) => client.buffer_from_host_buffer(&[*v], &[], None)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let t_up = t0.elapsed();
        let refs: Vec<&xla::PjRtBuffer> = buffers.iter().collect();
        let out = self.execute_buffers(&refs)?;
        let mut stats = self.stats.lock().unwrap();
        stats.upload_time += t_up;
        Ok(out)
    }

    fn check_input_refs(&self, inputs: &[InputRef]) -> Result<()> {
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.meta.file,
                self.meta.inputs.len(),
                inputs.len()
            );
        }
        for (got, spec) in inputs.iter().zip(&self.meta.inputs) {
            if got.dtype() != spec.dtype || got.shape() != spec.shape {
                bail!(
                    "{}: input {:?} mismatch: manifest {} {:?}, got {} {:?}",
                    self.meta.file,
                    spec.name,
                    spec.dtype,
                    spec.shape,
                    got.dtype(),
                    got.shape()
                );
            }
        }
        Ok(())
    }

    /// Execute with pre-staged device buffers (the hot path: the trainer
    /// re-uploads only the tensors that changed since the previous step).
    pub fn execute_buffers(&self, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<Vec<f32>>> {
        let t0 = Instant::now();
        let results = self
            .exe
            .execute_b(inputs)
            .with_context(|| format!("executing {}", self.meta.file))?;
        let t_exec = t0.elapsed();

        let t1 = Instant::now();
        let tuple = results[0][0]
            .to_literal_sync()
            .context("fetching result tuple")?;
        let parts = tuple.to_tuple().context("untupling result")?;
        if parts.len() != self.meta.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.meta.file,
                self.meta.outputs.len(),
                parts.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, spec) in parts.iter().zip(&self.meta.outputs) {
            let v: Vec<f32> = lit
                .to_vec()
                .with_context(|| format!("reading output {:?}", spec.name))?;
            if v.len() != spec.elements() {
                bail!(
                    "{}: output {:?} has {} elements, manifest says {}",
                    self.meta.file,
                    spec.name,
                    v.len(),
                    spec.elements()
                );
            }
            out.push(v);
        }
        let t_down = t1.elapsed();

        let mut stats = self.stats.lock().unwrap();
        stats.executions += 1;
        stats.exec_time += t_exec;
        stats.download_time += t_down;
        Ok(out)
    }
}

impl std::fmt::Debug for Executable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executable")
            .field("file", &self.meta.file)
            .field("inputs", &self.meta.inputs.len())
            .field("outputs", &self.meta.outputs.len())
            .field("compile_time", &self.compile_time)
            .finish()
    }
}
