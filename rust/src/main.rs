//! `collage` — the Layer-3 coordinator CLI.
//!
//! Subcommands:
//!   train       pretrain a model under one precision plan (scheme × format)
//!   eval        evaluate a checkpoint on the validation split
//!   experiment  regenerate a paper table/figure (see --list)
//!   stability   fault-injection × guardrail recovery grid
//!   memory      analytic peak-memory report for any (model, plan)
//!   inspect     dump manifest/artifact information
//!   dp-train    data-parallel training demo (threaded workers)
//!   dp-proc     multi-process data parallelism with fp8 compressed allreduce
//!   serve       multi-tenant training service (NDJSON over TCP)
//!   submit      submit a run to a serve instance and stream telemetry

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};
use collage::coordinator::checkpoint::Checkpoint;
use collage::coordinator::config::RunConfig;
use collage::coordinator::guard::GuardConfig;
use collage::coordinator::metrics::{MetricsLog, StepRow};
use collage::coordinator::proxy::{self, ProxyConfig};
use collage::coordinator::trainer::Trainer;
use collage::data::faults::FaultSpec;
use collage::data::batches::{BatchIterator, Split};
use collage::data::synthetic::{CorpusConfig, SyntheticCorpus};
use collage::experiments;
use collage::model::config as model_config;
use collage::model::memory::MemoryModel;
use collage::numerics::format::FloatFormat;
use collage::optim::adamw::AdamW;
use collage::optim::plan::{PrecisionPlan, ALL_SCHEMES};
use collage::parallel::proc::{self as dp_proc, DpProcConfig, WorkerSpawn};
use collage::parallel::worker::DataParallel;
use collage::runtime::{Manifest, Runtime};
use collage::serve::client::submit_lines;
use collage::serve::protocol::{build_request, RequestLimits};
use collage::serve::server::{ServeConfig, Server};
use collage::util::cli::{ArgSpec, Args};
use collage::util::threadpool::default_workers;
use collage::util::json::Obj;
use collage::util::table::{fnum, Table};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn usage() -> String {
    "collage — Collage low-precision LLM-training framework (ICML 2024 reproduction)\n\n\
     USAGE:\n  collage <SUBCOMMAND> [OPTIONS]\n\n\
     SUBCOMMANDS:\n\
       train        pretrain under one precision plan (strategy × format)\n\
       eval         evaluate a checkpoint\n\
       experiment   regenerate a paper table/figure (--list to enumerate)\n\
       stability    fault-injection × guardrail recovery grid (stability_grid.csv)\n\
       memory       analytic peak-memory report (any plan; --format for fp8 rows)\n\
       inspect      show artifact manifest details\n\
       dp-train     threaded data-parallel training\n\
       dp-proc      multi-process data parallelism: sharded optimizer state,\n\
                    error-feedback fp8-compressed gradient allreduce\n\
       serve        multi-tenant training service (NDJSON telemetry over TCP)\n\
       submit       submit a run to a serve instance and stream its telemetry\n\n\
     Plans combine a scheme (--strategy) with a storage format (--format),\n\
     optionally with loss-scaled δθ words — a static exponent\n\
     (+delta-scale=<pow2>) or the adaptive controller (+delta-scale=auto,\n\
     +delta-scale=auto:<k0>), which backs k off on saturation and grows it\n\
     while updates underflow:\n\
       collage train --format fp8e4m3 --strategy collage-light-3\n\
       collage train --strategy collage-light@fp8e4m3+delta-scale=8\n\
       collage train --strategy collage-light-3@fp8e4m3+delta-scale=auto\n\
       collage train --strategy collage-light-3@mxfp4+delta-scale=auto\n\n\
     Training can run under a spike guardrail (rollback recovery) and with\n\
     deterministic fault injection:\n\
       collage train --guard on --fault outlier-burst:start=230,window=16,scale=12\n\
       collage stability --quick\n\n\
     Run `collage <SUBCOMMAND> --help` for options.\n"
        .to_string()
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print!("{}", usage());
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "train" => cmd_train(rest),
        "eval" => cmd_eval(rest),
        "experiment" => cmd_experiment(rest),
        "stability" => cmd_stability(rest),
        "memory" => cmd_memory(rest),
        "inspect" => cmd_inspect(rest),
        "dp-train" => cmd_dp_train(rest),
        "dp-proc" => cmd_dp_proc(rest),
        "dp-proc-worker" => cmd_dp_proc_worker(rest),
        "serve" => cmd_serve(rest),
        "submit" => cmd_submit(rest),
        "--help" | "-h" | "help" => {
            print!("{}", usage());
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}\n\n{}", usage()),
    }
}

fn artifacts_opt(spec: ArgSpec) -> ArgSpec {
    spec.opt("artifacts", "artifacts", "artifact directory (make artifacts)")
}

fn cmd_train(args: &[String]) -> Result<()> {
    let spec = artifacts_opt(
        ArgSpec::new("collage train", "Pretrain a model under one precision plan")
            .opt("model", "small", "model config (tiny|tiny2x|small|medium|big)")
            .opt(
                "strategy",
                "collage-plus",
                "precision scheme (a|collage-light[-3]|collage-plus[-3]|dmw|d|kahan|sr|fp32, \
                 a combined scheme@format, optionally +delta-scale=<pow2>|auto[:<k0>])",
            )
            .opt("format", "", "storage format (bf16|fp16|fp8e4m3|fp8e5m2|mxfp4|fp32)")
            .opt("steps", "200", "optimizer steps")
            .opt("warmup", "20", "warmup steps")
            .opt("lr", "1e-3", "peak learning rate")
            .opt("beta2", "", "β₂ override (artifact path needs a matching export)")
            .opt("seed", "1234", "rng seed")
            .opt("eval-every", "50", "eval cadence (0 = end only)")
            .opt("log-every", "10", "stdout cadence")
            .opt("corpus-tokens", "1048576", "synthetic corpus size")
            .opt("csv", "", "write per-step metrics CSV here")
            .opt("checkpoint-dir", "", "checkpoint directory (resume if present)")
            .opt("checkpoint-every", "0", "checkpoint cadence")
            .opt("proxy-params", "8192", "parameter count for the proxy fallback path")
            .opt(
                "guard",
                "",
                "spike guardrail: \"on\" or key=value,... over window/spike-factor/\
                 update-factor/max-rollbacks/cooldown/skip/k-backoff/retain-every",
            )
            .opt(
                "fault",
                "",
                "inject faults (proxy path): ';'-separated kind:key=value,... specs \
                 (outlier-burst|loss-spike|update-shrink)",
            ),
    );
    let a = spec.parse(args)?;
    let plan = PrecisionPlan::parse_with_format(a.get("strategy"), a.get("format"))?;
    let guard = match a.get("guard") {
        "" => None,
        s => Some(s.parse::<GuardConfig>().context("parsing --guard")?),
    };
    let cfg = RunConfig {
        model: a.get("model").to_string(),
        plan,
        steps: a.u64("steps")?,
        warmup: a.u64("warmup")?,
        lr: a.f64("lr")?,
        beta2: parse_opt_f64(a.get("beta2"))?,
        seed: a.u64("seed")?,
        eval_every: a.u64("eval-every")?,
        log_every: a.u64("log-every")?,
        corpus_tokens: a.usize("corpus-tokens")?,
        checkpoint_dir: non_empty(a.get("checkpoint-dir")),
        checkpoint_every: a.u64("checkpoint-every")?,
        guard,
        ..Default::default()
    };
    // AOT artifacts cover only the bf16 row of the plan space; every other
    // plan — and any build without artifacts/PJRT — trains end-to-end on
    // the pure-Rust proxy objective through the same fused plan kernels.
    // Only *environment* failures (no PJRT backend / no artifact dir)
    // trigger the fallback: errors from the actual training run — bad
    // model names, checkpoint mismatches, CSV I/O — propagate.
    if plan.as_strategy().is_some() {
        match artifact_runtime(&a) {
            Ok((runtime, manifest)) => return train_artifacts(runtime, manifest, &a, cfg),
            Err(e) => eprintln!(
                "artifact runtime unavailable ({e:#}); \
                 falling back to the pure-Rust proxy trainer"
            ),
        }
    }
    train_proxy(&a, &cfg)
}

/// The fallible environment half of the artifact path: PJRT client +
/// manifest.  Failure here (stub backend, missing `make artifacts`) is
/// what legitimizes the proxy fallback.
fn artifact_runtime(a: &Args) -> Result<(Arc<Runtime>, Manifest)> {
    let runtime = Runtime::cpu()?;
    let manifest = Manifest::load(a.get("artifacts"))?;
    Ok((runtime, manifest))
}

/// The original artifact-backed training path (bf16-row plans only).
fn train_artifacts(
    runtime: Arc<Runtime>,
    manifest: Manifest,
    a: &Args,
    cfg: RunConfig,
) -> Result<()> {
    println!(
        "platform={} devices={} model={} plan={}",
        runtime.platform(),
        runtime.device_count(),
        cfg.model,
        cfg.plan.paper_name()
    );
    let mut trainer = Trainer::new(runtime, &manifest, cfg)?;
    let outcome = trainer.run()?;
    println!(
        "done: steps={} train_ppl={:.3} val_ppl={:.3} edq_ratio={:.4} lost={:.2}% {:.1} ms/step ({:.0} tok/s)",
        outcome.steps,
        outcome.train_ppl,
        outcome.val_ppl,
        outcome.edq_ratio,
        outcome.lost_frac * 100.0,
        outcome.step_time * 1e3,
        outcome.tokens_per_sec
    );
    let csv = a.get("csv");
    if !csv.is_empty() {
        outcome.log.write_csv(Path::new(csv))?;
        println!("metrics -> {csv}");
    }
    Ok(())
}

/// Artifact-free training on the least-squares proxy objective: any plan,
/// full per-step `StepStats` (EDQ + lost-frac) at the logging cadence.
fn train_proxy(a: &Args, cfg: &RunConfig) -> Result<()> {
    let pcfg = ProxyConfig {
        plan: cfg.plan,
        n: a.usize("proxy-params")?,
        steps: cfg.steps,
        warmup: cfg.warmup,
        lr: cfg.lr,
        beta2: cfg.beta2.unwrap_or(0.95),
        seed: cfg.seed,
        log_every: cfg.log_every,
        guard: cfg.guard,
        faults: FaultSpec::parse_list(a.get("fault"))?,
        ..Default::default()
    };
    println!(
        "proxy-train: plan={} ({} B/param) n={} steps={} (least-squares teacher objective)",
        cfg.plan,
        cfg.plan.bytes_per_param(),
        pcfg.n,
        pcfg.steps
    );
    let o = proxy::run(&pcfg)?;
    let guard_suffix = if pcfg.guard.is_some() {
        format!(" guard: trips={} rollbacks={} steps_lost={}", o.guard_trips, o.rollbacks, o.steps_lost)
    } else {
        String::new()
    };
    println!(
        "done: steps={} final_loss={:.4e} edq_ratio={:.4} lost={:.2}% {:.2} ms/step{guard_suffix}",
        o.steps,
        o.final_loss,
        o.edq_ratio,
        o.lost_frac * 100.0,
        o.step_time * 1e3
    );
    let csv = a.get("csv");
    if !csv.is_empty() {
        o.log.write_csv(Path::new(csv))?;
        println!("metrics -> {csv}");
    }
    Ok(())
}

fn cmd_eval(args: &[String]) -> Result<()> {
    let spec = artifacts_opt(
        ArgSpec::new("collage eval", "Evaluate a checkpoint on the validation split")
            .req("checkpoint", "checkpoint file")
            .opt("eval-batches", "16", "validation batches")
            .opt("seed", "1234", "corpus seed (must match training)")
            .opt("corpus-tokens", "1048576", "synthetic corpus size"),
    );
    let a = spec.parse(args)?;
    let ck = Checkpoint::load(Path::new(a.get("checkpoint")))?;
    let runtime = Runtime::cpu()?;
    let manifest = Manifest::load(a.get("artifacts"))?;
    let cfg = RunConfig {
        model: ck.model.clone(),
        plan: ck.state.plan,
        eval_batches: a.usize("eval-batches")?,
        seed: a.u64("seed")?,
        corpus_tokens: a.usize("corpus-tokens")?,
        ..Default::default()
    };
    let mut trainer = Trainer::new(runtime, &manifest, cfg)?;
    trainer.set_theta(ck.state.theta())?;
    let loss = trainer.evaluate()?;
    println!(
        "checkpoint step {} model {}: val_loss={loss:.4} val_ppl={:.3}",
        ck.step,
        ck.model,
        loss.exp()
    );
    Ok(())
}

fn cmd_experiment(args: &[String]) -> Result<()> {
    let spec = artifacts_opt(
        ArgSpec::new("collage experiment", "Regenerate a paper table or figure")
            .pos("id", "experiment id (table2..table12, fig1..fig7to12)")
            .opt("out-dir", "runs", "output directory for CSVs/tables")
            .flag("quick", "reduced step counts (CI mode)")
            .flag("list", "list available experiments"),
    );
    let a = spec.parse(args)?;
    if a.flag("list") || a.positional.is_empty() {
        experiments::list().print();
        return Ok(());
    }
    let id = &a.positional[0];
    experiments::run(
        id,
        Path::new(a.get("artifacts")),
        &PathBuf::from(a.get("out-dir")).join(id),
        a.flag("quick"),
    )
}

fn cmd_stability(args: &[String]) -> Result<()> {
    let spec = ArgSpec::new(
        "collage stability",
        "Fault-injection × guardrail recovery grid (writes stability_grid.csv)",
    )
    .opt("out-dir", "runs", "output directory for the grid CSV + table")
    .flag("quick", "headline plan only (CI mode)");
    let a = spec.parse(args)?;
    experiments::run(
        "stability",
        Path::new("artifacts"), // unused: the stability grid is proxy-only
        &PathBuf::from(a.get("out-dir")).join("stability"),
        a.flag("quick"),
    )
}

fn cmd_memory(args: &[String]) -> Result<()> {
    let spec = ArgSpec::new("collage memory", "Analytic peak-memory report")
        .opt("model", "gpt-6.7b", "model (paper sizes: gpt-125m..gpt-30b, openllama-7b)")
        .opt("format", "", "storage format rows instead of the bf16 strategy zoo")
        .opt("micro-batch", "1", "micro batch size")
        .opt("seq-len", "2048", "sequence length")
        .opt("tp", "8", "tensor parallelism")
        .opt("pp", "1", "pipeline parallelism")
        .opt("budget-gb", "40", "per-GPU memory budget");
    let a = spec.parse(args)?;
    let Some(cfg) = model_config::find(a.get("model")) else {
        bail!("unknown model {:?}", a.get("model"));
    };
    let mut m = MemoryModel::default();
    m.budget_per_gpu = a.f64("budget-gb")? * (1u64 << 30) as f64;
    let (ubs, seq, tp, pp) =
        (a.usize("micro-batch")?, a.usize("seq-len")?, a.usize("tp")?, a.usize("pp")?);
    // Default rows: the legacy bf16 strategy zoo; with --format, the full
    // scheme column at that storage format (Table 2/8/12 generalized).
    let plans: Vec<PrecisionPlan> = if a.get("format").is_empty() {
        collage::optim::strategy::ALL_STRATEGIES.iter().map(|&s| s.into()).collect()
    } else {
        let fmt: FloatFormat = a.get("format").parse()?;
        // Block-scaled formats support only the plain/MCF rows; skip the
        // schemes `PrecisionPlan::validate` would reject (e.g. kahan@mxfp4).
        ALL_SCHEMES
            .iter()
            .map(|&sch| PrecisionPlan::new(fmt, sch))
            .filter(|p| p.validate().is_ok())
            .collect()
    };
    let mut t = Table::new(format!(
        "peak memory — {} (UBS={ubs}, seq={seq}, TP={tp}, PP={pp}, {} params)",
        cfg.name,
        cfg.n_params()
    ));
    t.header(&["plan", "state GB", "act GB", "total GB", "per-GPU GB", "fits?"]);
    for plan in plans {
        let p = m.peak(cfg, plan, ubs, seq, tp, pp);
        t.row(vec![
            plan.paper_name(),
            fnum(p.state_bytes / 1073741824.0, 1),
            fnum(p.activation_bytes / 1073741824.0, 1),
            fnum(p.total_gb(), 1),
            fnum(p.per_gpu_gb(), 1),
            (if p.per_gpu_bytes <= m.budget_per_gpu { "OK" } else { "OOM" }).to_string(),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_inspect(args: &[String]) -> Result<()> {
    let spec = artifacts_opt(ArgSpec::new("collage inspect", "Show artifact manifest details"));
    let a = spec.parse(args)?;
    let manifest = Manifest::load(a.get("artifacts"))?;
    println!("artifact dir: {}", manifest.dir.display());
    println!("block: {}  metric columns: {:?}", manifest.block, manifest.metric_names);
    let mut t = Table::new("configs");
    t.header(&["name", "vocab", "d_model", "layers", "heads", "seq", "batch", "params", "padded"]);
    for (name, m) in &manifest.configs {
        t.row(vec![
            name.clone(),
            m.vocab.to_string(),
            m.d_model.to_string(),
            m.n_layers.to_string(),
            m.n_heads.to_string(),
            m.seq_len.to_string(),
            m.micro_batch.to_string(),
            m.n_params.to_string(),
            m.padded_len.to_string(),
        ]);
    }
    t.print();
    let mut t = Table::new("artifacts");
    t.header(&["file", "kind", "config", "option", "beta2", "inputs", "outputs"]);
    for art in &manifest.artifacts {
        t.row(vec![
            art.file.clone(),
            format!("{:?}", art.kind),
            art.config.clone(),
            art.option.clone().unwrap_or_else(|| "-".into()),
            art.beta2.map(|b| b.to_string()).unwrap_or_else(|| "-".into()),
            art.inputs.len().to_string(),
            art.outputs.len().to_string(),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_dp_train(args: &[String]) -> Result<()> {
    let spec = artifacts_opt(
        ArgSpec::new(
            "collage dp-train",
            "Data-parallel training: threaded workers + deterministic all-reduce + \
             bit-exact Rust optimizer",
        )
        .opt("model", "tiny", "model config")
        .opt(
            "strategy",
            "collage-plus",
            "precision scheme (or scheme@format[+delta-scale=<pow2>|auto[:<k0>]])",
        )
        .opt("format", "", "storage format (bf16|fp16|fp8e4m3|fp8e5m2|mxfp4|fp32)")
        .opt("workers", "4", "data-parallel worker count")
        .opt("steps", "100", "global steps")
        .opt("lr", "1e-3", "peak learning rate")
        .opt("beta2", "0.95", "AdamW β₂")
        .opt("seed", "1234", "rng seed")
        .opt("log-every", "10", "stdout cadence"),
    );
    let a = spec.parse(args)?;
    let manifest = Manifest::load(a.get("artifacts"))?;
    let model = a.get("model").to_string();
    let plan = PrecisionPlan::parse_with_format(a.get("strategy"), a.get("format"))?;
    let workers = a.usize("workers")?;
    let steps = a.u64("steps")?;
    let seed = a.u64("seed")?;
    let meta = manifest.model(&model)?.clone();

    let corpus = SyntheticCorpus::generate(CorpusConfig {
        vocab: meta.vocab,
        n_tokens: 1 << 20,
        seed,
        ..Default::default()
    });
    let mut iters: Vec<BatchIterator> = (0..workers)
        .map(|w| {
            BatchIterator::new(
                &corpus,
                Split::Train,
                meta.micro_batch,
                meta.seq_len,
                seed + w as u64,
            )
        })
        .collect::<Result<_>>()?;

    let opt = AdamW::for_plan(plan, a.f64("beta2")?);
    let mut dp = DataParallel::new(&manifest, &model, plan, workers, opt, seed)?;
    let schedule =
        collage::coordinator::schedule::LrSchedule::new(a.f64("lr")?, steps / 10, steps, 0.1);
    let log_every = a.u64("log-every")?;
    println!(
        "dp-train: {workers} workers × micro-batch {} (global batch {}) plan {}",
        meta.micro_batch,
        workers * meta.micro_batch,
        plan.paper_name()
    );
    let t0 = std::time::Instant::now();
    for step in 1..=steps {
        let shards: Vec<_> = iters.iter_mut().map(|it| it.next_batch()).collect();
        let r = dp.step(&shards, schedule.at(step) as f32)?;
        if log_every > 0 && step % log_every == 0 {
            let ds = r.stats.delta_log_suffix();
            println!(
                "[{step}/{steps}] loss={:.4} ppl={:.3} gnorm={:.3} edq={:.3} lost={:.1}%{ds}",
                r.loss,
                r.loss.exp(),
                r.grad_norm,
                r.stats.edq.edq_ratio,
                r.stats.lost_frac * 100.0
            );
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    let tokens = steps as f64 * (workers * meta.micro_batch * meta.seq_len) as f64;
    println!(
        "dp-train done: {:.1}s, {:.0} tokens/s across {workers} workers",
        dt,
        tokens / dt
    );
    Ok(())
}

fn cmd_dp_proc(args: &[String]) -> Result<()> {
    let spec = ArgSpec::new(
        "collage dp-proc",
        "Multi-process data parallelism: each rank owns a chunk-aligned slice \
         of the optimizer state; gradients cross the wire fp8-compressed with \
         MCF error feedback.  Step rows and the final state digest are \
         bit-identical at any rank and worker count.",
    )
    .opt(
        "plan",
        "collage-plus",
        "precision plan (scheme[@format][+delta-scale=<pow2>|auto[:<k0>]]; sr excluded)",
    )
    .opt("wire", "fp8e4m3", "gradient wire format (element-wise: bf16|fp16|fp8e4m3|fp8e5m2)")
    .opt("ranks", "2", "process count (rank 0 is the leader and also computes)")
    .opt("shards", "0", "simulated data shards (0 = one per rank; must be divisible by ranks)")
    .opt("params", "32768", "proxy parameter count (needs >= ranks chunks of 16384)")
    .opt("steps", "60", "optimizer steps")
    .opt("warmup", "6", "warmup steps")
    .opt("lr", "2e-2", "peak learning rate")
    .opt("min-lr-ratio", "0.1", "cosine floor as a fraction of peak")
    .opt("beta2", "0.95", "AdamW β₂")
    .opt("seed", "1234", "rng seed")
    .opt("log-every", "10", "leader stdout cadence (0 = summary only)")
    .opt("workers", "0", "kernel threads per rank (0 = CPU count)")
    .opt("theta-scale", "8", "teacher parameter scale")
    .flag("json", "emit NDJSON events instead of human lines");
    let a = spec.parse(args)?;
    let ranks = a.usize("ranks")?;
    let shards = a.usize("shards")?;
    let workers = a.usize("workers")?;
    let cfg = DpProcConfig {
        plan: a.get("plan").parse()?,
        wire: a.get("wire").parse()?,
        ranks,
        shards: if shards == 0 { ranks } else { shards },
        n: a.usize("params")?,
        steps: a.u64("steps")?,
        warmup: a.u64("warmup")?,
        lr: a.f64("lr")?,
        min_lr_ratio: a.f64("min-lr-ratio")?,
        beta2: a.f64("beta2")?,
        seed: a.u64("seed")?,
        log_every: a.u64("log-every")?,
        workers: if workers == 0 { default_workers() } else { workers },
        theta_scale: a.f32("theta-scale")?,
        json: a.flag("json"),
        spawn: WorkerSpawn::Process,
    };
    if !cfg.json && cfg.log_every > 0 {
        println!(
            "dp-proc: ranks={} shards={} plan={} wire={} n={} steps={} workers={}",
            cfg.ranks, cfg.shards, cfg.plan, cfg.wire.name, cfg.n, cfg.steps, cfg.workers
        );
    }
    dp_proc::run(&cfg)?;
    Ok(())
}

/// Internal entry point: one worker rank of a `dp-proc` run.  Spawned by
/// the leader with its rendezvous address — not meant to be run by hand.
fn cmd_dp_proc_worker(args: &[String]) -> Result<()> {
    let spec = ArgSpec::new(
        "collage dp-proc-worker",
        "One worker rank of a dp-proc run (spawned by the leader; internal)",
    )
    .req("connect", "leader address (host:port)")
    .req("rank", "this worker's rank (1-based)");
    let a = spec.parse(args)?;
    dp_proc::worker_main(a.get("connect"), a.usize("rank")?)
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let spec = ArgSpec::new(
        "collage serve",
        "Multi-tenant training service: concurrent proxy runs over one shared \
         worker pool, NDJSON telemetry per connection",
    )
    .opt("addr", "127.0.0.1:7734", "bind address (port 0 = ephemeral)")
    .opt("max-inflight", "2", "runs allowed to compute a step concurrently")
    .opt("max-runs", "0", "exit after serving N connections (0 = run forever)")
    .opt("worker-cap", "0", "clamp per-run worker counts to this (0 = CPU count)")
    .opt("max-request-bytes", "1048576", "reject request lines longer than this")
    .opt("max-params", "4194304", "reject runs with more proxy parameters")
    .opt("max-steps", "1000000", "reject runs with more optimizer steps")
    .opt("checkpoint-root", "", "write per-run checkpoints under this directory")
    .flag("quiet", "no per-connection stdout notes");
    let a = spec.parse(args)?;
    let mut limits = RequestLimits {
        max_params: a.usize("max-params")?,
        max_steps: a.u64("max-steps")?,
        ..Default::default()
    };
    let cap = a.usize("worker-cap")?;
    if cap > 0 {
        limits.worker_cap = cap;
    }
    let cfg = ServeConfig {
        addr: a.get("addr").to_string(),
        max_inflight: a.usize("max-inflight")?.max(1),
        max_runs: a.usize("max-runs")?,
        limits,
        max_request_bytes: a.usize("max-request-bytes")?,
        checkpoint_root: non_empty(a.get("checkpoint-root")).map(PathBuf::from),
        quiet: a.flag("quiet"),
    };
    let server = Server::bind(cfg)?;
    println!("collage serve: listening on {}", server.local_addr()?);
    server.run()
}

fn cmd_submit(args: &[String]) -> Result<()> {
    let spec = ArgSpec::new(
        "collage submit",
        "Submit one run to a collage serve instance and stream its NDJSON \
         telemetry to stdout",
    )
    .opt("addr", "127.0.0.1:7734", "server address")
    .opt(
        "plan",
        "collage-plus",
        "precision plan (scheme[@format][+delta-scale=<pow2>|auto[:<k0>]])",
    )
    .opt("params", "8192", "proxy parameter count")
    .opt("steps", "200", "optimizer steps")
    .opt("warmup", "", "warmup steps (server default if empty)")
    .opt("lr", "", "peak learning rate (server default if empty)")
    .opt("beta2", "", "AdamW β₂ (server default if empty)")
    .opt("seed", "", "rng seed (server default if empty)")
    .opt("log-every", "1", "telemetry cadence (0 = terminal events only)")
    .opt("workers", "", "pool workers for this run (server clamps)")
    .opt("theta-scale", "", "teacher parameter scale (server default if empty)")
    .opt("checkpoint-every", "", "checkpoint cadence (server must enable a root)")
    .opt("guard", "", "spike guardrail: \"on\" or key=value,... (see collage train)")
    .opt("fault", "", "inject faults: ';'-separated kind:key=value,... specs")
    .opt("csv", "", "also write the streamed step rows as CSV here");
    let a = spec.parse(args)?;

    let mut c = Obj::new();
    c.insert("n", a.u64("params")?);
    c.insert("steps", a.u64("steps")?);
    c.insert("log_every", a.u64("log-every")?);
    for (key, flag) in [("warmup", "warmup"), ("seed", "seed"), ("workers", "workers"),
                        ("checkpoint_every", "checkpoint-every")]
    {
        if !a.get(flag).is_empty() {
            c.insert(key, a.u64(flag)?);
        }
    }
    for (key, flag) in [("lr", "lr"), ("beta2", "beta2"), ("theta_scale", "theta-scale")] {
        if !a.get(flag).is_empty() {
            c.insert(key, a.f64(flag)?);
        }
    }
    let request = build_request(
        a.get("plan"),
        c,
        non_empty(a.get("guard")).as_deref(),
        non_empty(a.get("fault")).as_deref(),
    );

    // Stream every event line verbatim as it arrives; optionally decode the
    // step events back into rows for a local CSV.
    let mut log = MetricsLog::default();
    let want_csv = !a.get("csv").is_empty();
    let outcome = submit_lines(a.get("addr"), &request, |v| {
        println!("{}", v.dump());
        if want_csv && v.opt("event").and_then(|e| e.as_str().ok()) == Some("step") {
            if let Ok(row) = v.decode::<StepRow>() {
                log.push(row);
            }
        }
    })?;
    let done = outcome.into_done()?;
    if want_csv {
        log.write_csv(Path::new(a.get("csv")))?;
        eprintln!("metrics -> {}", a.get("csv"));
    }
    eprintln!(
        "done: steps={} final_loss={:.4e} edq_ratio={:.4} lost={:.2}% digest={:016x}",
        done.steps,
        done.final_loss,
        done.edq_ratio,
        done.lost_frac * 100.0,
        done.state_digest
    );
    Ok(())
}

fn parse_opt_f64(s: &str) -> Result<Option<f64>> {
    if s.is_empty() {
        Ok(None)
    } else {
        Ok(Some(s.parse().context("parsing float option")?))
    }
}

fn non_empty(s: &str) -> Option<String> {
    (!s.is_empty()).then(|| s.to_string())
}
