"""Layer-2: GPT-style transformer LM over a flat parameter vector.

The model follows the NeMo-Megatron GPT recipe used by the paper (App. E.2):
pre-LN decoder blocks, rotary position embeddings (RoPE, fraction 1.0),
GELU MLP with 4× expansion, untied embedding / output head, causal
attention, sequence-major [B, T] token batches.

Mixed precision matches the paper's setup: weights and activations are bf16,
GEMMs accumulate in fp32 ("mixed-precision for GEMM", Sec. 2.1), layernorm
statistics and softmax run in fp32, and the loss is fp32.

All parameters live in ONE flat f32 vector (bf16-representable values; see
DESIGN.md "flat-parameter design").  ``PARAM_TABLE`` defines the canonical
(name, shape, offset) layout which the Rust coordinator reads from
``manifest.json`` for checkpointing and inspection.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels.mcf import BLOCK

# ---------------------------------------------------------------------------
# Model configuration zoo.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture + micro-batch geometry for one AOT artifact."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    seq_len: int
    micro_batch: int

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# Runnable configs (CPU-PJRT scale). ``medium`` is the end-to-end example
# config (~5M params — the largest that trains a few hundred steps in
# minutes on CPU); ``tiny`` is the test config.
CONFIGS = {
    "tiny": ModelConfig("tiny", vocab=256, d_model=64, n_layers=2, n_heads=2, seq_len=32, micro_batch=4),
    # tiny with doubled micro-batch — the "global batch size" ablation axis
    # of paper Table 6 (batch geometry is baked into each artifact).
    "tiny2x": ModelConfig("tiny2x", vocab=256, d_model=64, n_layers=2, n_heads=2, seq_len=32, micro_batch=8),
    "small": ModelConfig("small", vocab=512, d_model=128, n_layers=4, n_heads=4, seq_len=64, micro_batch=8),
    "medium": ModelConfig("medium", vocab=1024, d_model=256, n_layers=6, n_heads=8, seq_len=128, micro_batch=8),
    "big": ModelConfig("big", vocab=4096, d_model=512, n_layers=8, n_heads=8, seq_len=256, micro_batch=4),
}


# ---------------------------------------------------------------------------
# Flat parameter layout.
# ---------------------------------------------------------------------------


def param_table(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Canonical ordered (name, shape) list for the flat vector."""
    t: List[Tuple[str, Tuple[int, ...]]] = [("embed", (cfg.vocab, cfg.d_model))]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        t += [
            (p + "ln1.g", (cfg.d_model,)),
            (p + "ln1.b", (cfg.d_model,)),
            (p + "attn.wqkv", (cfg.d_model, 3 * cfg.d_model)),
            (p + "attn.bqkv", (3 * cfg.d_model,)),
            (p + "attn.wo", (cfg.d_model, cfg.d_model)),
            (p + "attn.bo", (cfg.d_model,)),
            (p + "ln2.g", (cfg.d_model,)),
            (p + "ln2.b", (cfg.d_model,)),
            (p + "mlp.wi", (cfg.d_model, cfg.d_ff)),
            (p + "mlp.bi", (cfg.d_ff,)),
            (p + "mlp.wo", (cfg.d_ff, cfg.d_model)),
            (p + "mlp.bo", (cfg.d_model,)),
        ]
    t += [("lnf.g", (cfg.d_model,)), ("lnf.b", (cfg.d_model,)), ("head", (cfg.d_model, cfg.vocab))]
    return t


def num_params(cfg: ModelConfig) -> int:
    """True (unpadded) parameter count."""
    return sum(math.prod(s) for _, s in param_table(cfg))


def padded_len(cfg: ModelConfig) -> int:
    """Flat-vector length padded to the Pallas BLOCK multiple."""
    n = num_params(cfg)
    return (n + BLOCK - 1) // BLOCK * BLOCK


def param_offsets(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...], int]]:
    """(name, shape, offset) manifest rows."""
    rows, off = [], 0
    for name, shape in param_table(cfg):
        rows.append((name, shape, off))
        off += math.prod(shape)
    return rows


def split_flat(flat, cfg: ModelConfig):
    """Slice the flat vector into the ordered per-tensor list (f32).

    Slices are static (offsets known at trace time).  Kept OUTSIDE the
    differentiated region: the VJP of a slice is a full-length `pad`, and
    ~50 of those per backward cost more than the whole forward (§Perf);
    differentiating w.r.t. the parts instead makes the cotangent a single
    concatenate.
    """
    parts, off = [], 0
    for _, shape in param_table(cfg):
        n = math.prod(shape)
        parts.append(jax.lax.slice(flat, (off,), (off + n,)).reshape(shape))
        off += n
    return parts


def params_from_parts(parts, cfg: ModelConfig, dtype):
    """Name the parts and cast to the compute dtype (the model sees only
    the bf16 hi component under the MCF strategies)."""
    return {
        name: p.astype(dtype)
        for (name, _), p in zip(param_table(cfg), parts)
    }


def unflatten(flat, cfg: ModelConfig, dtype):
    """Slice the flat vector into named tensors, cast to compute dtype."""
    return params_from_parts(split_flat(flat, cfg), cfg, dtype)


def init_params(seed: int, cfg: ModelConfig) -> jnp.ndarray:
    """GPT-2-style init, returned as a padded flat f32 vector of
    bf16-representable values (so the boundary invariant holds from step 0).
    """
    key = jax.random.PRNGKey(seed)
    chunks = []
    scale_out = 0.02 / math.sqrt(2.0 * cfg.n_layers)
    for name, shape in param_table(cfg):
        key, sub = jax.random.split(key)
        if name.endswith((".b", ".bi", ".bo", ".bqkv", "ln1.b", "ln2.b", "lnf.b")):
            w = jnp.zeros(shape, jnp.float32)
        elif name.endswith((".g",)):
            w = jnp.ones(shape, jnp.float32)
        elif name.endswith(("attn.wo", "mlp.wo")):
            w = jax.random.normal(sub, shape, jnp.float32) * scale_out
        else:
            w = jax.random.normal(sub, shape, jnp.float32) * 0.02
        chunks.append(w.reshape(-1))
    flat = jnp.concatenate(chunks)
    flat = jnp.pad(flat, (0, padded_len(cfg) - flat.shape[0]))
    # Round to bf16-representable values: the stored format is bf16.
    return flat.astype(jnp.bfloat16).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Forward pass.
# ---------------------------------------------------------------------------


def _layernorm(x, g, b):
    """LayerNorm with fp32 statistics (NeMo default), output in x.dtype."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
    return (y * g.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def _matmul(a, w):
    """Mixed-precision GEMM: low-precision operands, fp32 accumulation."""
    return jnp.matmul(a, w, preferred_element_type=jnp.float32).astype(a.dtype)


def _rope(x, positions):
    """Rotary position embedding (rotary fraction 1.0, paper App. E.2).

    x: [B, H, T, Dh]; positions: [T].
    """
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(
        -math.log(10000.0) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    angles = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # [T, half]
    cos = jnp.cos(angles)[None, None, :, :]
    sin = jnp.sin(angles)[None, None, :, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def _attention(x, p, prefix, cfg: ModelConfig):
    """Causal multi-head self-attention with fp32 softmax."""
    b, t, d = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    qkv = _matmul(x, p[prefix + "wqkv"]) + p[prefix + "bqkv"].astype(x.dtype)
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(z):
        return z.reshape(b, t, h, dh).transpose(0, 2, 1, 3)  # [B,H,T,Dh]

    positions = jnp.arange(t)
    q, k, v = heads(q), heads(k), heads(v)
    q, k = _rope(q, positions), _rope(k, positions)
    scores = jnp.einsum(
        "bhqd,bhkd->bhqk", q, k, preferred_element_type=jnp.float32
    ) / math.sqrt(dh)
    causal = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(causal[None, None], scores, -1e9)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, v, preferred_element_type=jnp.float32)
    ctx = ctx.astype(x.dtype).transpose(0, 2, 1, 3).reshape(b, t, d)
    return _matmul(ctx, p[prefix + "wo"]) + p[prefix + "bo"].astype(x.dtype)


def forward(flat, tokens, cfg: ModelConfig, compute_dtype=jnp.bfloat16):
    """Forward pass: flat params + tokens [B, T] -> fp32 logits [B, T, V]."""
    return forward_params(unflatten(flat, cfg, compute_dtype), tokens, cfg)


def forward_params(p, tokens, cfg: ModelConfig):
    """Forward pass over the named parameter dict (already compute-dtype)."""
    x = jnp.take(p["embed"], tokens, axis=0)  # [B, T, D]
    for i in range(cfg.n_layers):
        pre = f"layer{i}."
        h = _layernorm(x, p[pre + "ln1.g"], p[pre + "ln1.b"])
        x = x + _attention(h, p, pre + "attn.", cfg)
        h = _layernorm(x, p[pre + "ln2.g"], p[pre + "ln2.b"])
        h = _matmul(h, p[pre + "mlp.wi"]) + p[pre + "mlp.bi"].astype(x.dtype)
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
        h = _matmul(h, p[pre + "mlp.wo"]) + p[pre + "mlp.bo"].astype(x.dtype)
        x = x + h
    x = _layernorm(x, p["lnf.g"], p["lnf.b"])
    logits = jnp.matmul(x, p["head"], preferred_element_type=jnp.float32)
    return logits


def loss_fn(flat, tokens, targets, cfg: ModelConfig, compute_dtype=jnp.bfloat16):
    """Mean token cross-entropy in fp32. targets: [B, T] int32."""
    logits = forward(flat, tokens, cfg, compute_dtype)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def loss_and_grad(flat, tokens, targets, cfg: ModelConfig, compute_dtype=jnp.bfloat16):
    """(loss, fp32 grad w.r.t. the flat vector).

    Differentiates w.r.t. the per-tensor parts and concatenates the
    cotangents once (§Perf — see `split_flat`).  The gradient of the
    padded tail is identically zero; callers quantize g to bf16 per the
    storage policy.
    """
    parts = split_flat(flat, cfg)

    def loss_from_parts(parts):
        p = params_from_parts(parts, cfg, compute_dtype)
        logits = forward_params(p, tokens, cfg)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        return jnp.mean(nll)

    loss, part_grads = jax.value_and_grad(loss_from_parts)(parts)
    g = jnp.concatenate([x.reshape(-1) for x in part_grads])
    g = jnp.pad(g, (0, flat.shape[0] - g.shape[0]))
    return loss, g
