"""AOT exporter: lower every (model config × precision option) train step,
the eval step and the grad-only step to HLO **text** artifacts + manifest.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the Rust ``xla`` crate) rejects; the text parser reassigns
ids and round-trips cleanly.  See /opt/xla-example/README.md.

Run once via ``make artifacts``; the Rust binary is self-contained after.

Usage:
    python -m compile.aot --out-dir ../artifacts \
        [--configs tiny,small] [--options all] [--init-states]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_lib
from . import optim
from .kernels.mcf import BLOCK


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(dtype, shape):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _io_row(name, dtype, shape):
    return {"name": name, "dtype": dtype, "shape": list(shape)}


def _train_input_specs(cfg, n):
    b, t = cfg.micro_batch, cfg.seq_len
    return [
        ("tokens", _spec(jnp.int32, (b, t)), _io_row("tokens", "s32", (b, t))),
        ("targets", _spec(jnp.int32, (b, t)), _io_row("targets", "s32", (b, t))),
        ("lr", _spec(jnp.float32, ()), _io_row("lr", "f32", ())),
        ("bc1", _spec(jnp.float32, ()), _io_row("bc1", "f32", ())),
        ("bc2", _spec(jnp.float32, ()), _io_row("bc2", "f32", ())),
        ("seed", _spec(jnp.uint32, ()), _io_row("seed", "u32", ())),
    ]


def export_train(cfg, option, oc, out_dir, tag=""):
    """Lower one train step; returns its manifest entry.

    ``tag`` distinguishes β₂-variant artifacts (e.g. "b999_") so they never
    collide with the config-default export.
    """
    n = model_lib.padded_len(cfg)
    step = optim.make_train_step(option, cfg, oc)
    fixed = _train_input_specs(cfg, n)
    state_rows = optim.STATE_SPECS[option]
    specs = [s for _, s, _ in fixed] + [_spec(jnp.float32, (n,))] * len(state_rows)
    t0 = time.time()
    lowered = jax.jit(step, keep_unused=True).lower(*specs)
    text = to_hlo_text(lowered)
    fname = f"{cfg.name}_{tag}{option}_train.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    inputs = [row for _, _, row in fixed] + [
        _io_row(name, "f32", (n,)) for name, _ in state_rows
    ]
    outputs = [_io_row(name, "f32", (n,)) for name, _ in state_rows] + [
        _io_row("metrics", "f32", (optim.NUM_METRICS,))
    ]
    print(f"  {fname}: {len(text)} chars in {time.time()-t0:.1f}s")
    return {
        "file": fname,
        "kind": "train",
        "config": cfg.name,
        "option": option,
        "inputs": inputs,
        "outputs": outputs,
        "state": [{"name": nm, "semantic_dtype": dt} for nm, dt in state_rows],
        "metrics": list(optim.METRIC_NAMES),
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
    }


def export_eval(cfg, out_dir, compute_dtype=jnp.bfloat16, tag="eval"):
    n = model_lib.padded_len(cfg)
    b, t = cfg.micro_batch, cfg.seq_len
    step = optim.make_eval_step(cfg, compute_dtype)
    lowered = jax.jit(step, keep_unused=True).lower(
        _spec(jnp.int32, (b, t)), _spec(jnp.int32, (b, t)), _spec(jnp.float32, (n,))
    )
    text = to_hlo_text(lowered)
    fname = f"{cfg.name}_{tag}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    print(f"  {fname}: {len(text)} chars")
    return {
        "file": fname,
        "kind": tag,
        "config": cfg.name,
        "option": None,
        "inputs": [
            _io_row("tokens", "s32", (b, t)),
            _io_row("targets", "s32", (b, t)),
            _io_row("theta", "f32", (n,)),
        ],
        "outputs": [_io_row("loss", "f32", ())],
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
    }


def export_grad(cfg, out_dir, compute_dtype=jnp.bfloat16):
    """Forward+backward-only artifact for the data-parallel workers."""
    n = model_lib.padded_len(cfg)
    b, t = cfg.micro_batch, cfg.seq_len
    step = optim.make_grad_step(cfg, compute_dtype)
    lowered = jax.jit(step, keep_unused=True).lower(
        _spec(jnp.int32, (b, t)), _spec(jnp.int32, (b, t)), _spec(jnp.float32, (n,))
    )
    text = to_hlo_text(lowered)
    fname = f"{cfg.name}_grad.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    print(f"  {fname}: {len(text)} chars")
    return {
        "file": fname,
        "kind": "grad",
        "config": cfg.name,
        "option": None,
        "inputs": [
            _io_row("tokens", "s32", (b, t)),
            _io_row("targets", "s32", (b, t)),
            _io_row("theta", "f32", (n,)),
        ],
        "outputs": [_io_row("loss", "f32", ()), _io_row("grad", "f32", (n,))],
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
    }


def export_predict(cfg, out_dir, compute_dtype=jnp.bfloat16):
    """Last-position logits artifact (GLUE-style classification).

    Outputs the fp32 logits of the final sequence position per row; the
    coordinator scores only the label-candidate tokens (the standard
    LM-as-classifier evaluation), so accuracy is well-defined even when
    the bulk of the distribution sits on body tokens.
    """
    n = model_lib.padded_len(cfg)
    b, t = cfg.micro_batch, cfg.seq_len

    def step(tokens, theta):
        logits = model_lib.forward(theta, tokens, cfg, compute_dtype)
        return logits[:, -1, :]

    lowered = jax.jit(step, keep_unused=True).lower(
        _spec(jnp.int32, (b, t)), _spec(jnp.float32, (n,))
    )
    text = to_hlo_text(lowered)
    fname = f"{cfg.name}_predict.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    print(f"  {fname}: {len(text)} chars")
    return {
        "file": fname,
        "kind": "predict",
        "config": cfg.name,
        "option": None,
        "inputs": [
            _io_row("tokens", "s32", (b, t)),
            _io_row("theta", "f32", (n,)),
        ],
        "outputs": [_io_row("last_logits", "f32", (b, cfg.vocab))],
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
    }


def export_init(cfg, out_dir, seed=1234):
    """Initial bf16-representable flat parameter vector (npy, fp32)."""
    flat = np.asarray(model_lib.init_params(seed, cfg), np.float32)
    fname = f"{cfg.name}_init.npy"
    np.save(os.path.join(out_dir, fname), flat)
    print(f"  {fname}: {flat.shape[0]} params (padded)")
    return fname


def config_manifest(cfg):
    return {
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "seq_len": cfg.seq_len,
        "micro_batch": cfg.micro_batch,
        "n_params": model_lib.num_params(cfg),
        "padded_len": model_lib.padded_len(cfg),
        "param_table": [
            {"name": nm, "shape": list(sh), "offset": off}
            for nm, sh, off in model_lib.param_offsets(cfg)
        ],
    }


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--configs", default="tiny,tiny2x,small,medium")
    p.add_argument("--options", default="all")
    p.add_argument("--beta2", type=float, default=None,
                   help="override β₂ (default: per-config standard values)")
    p.add_argument("--seed", type=int, default=1234)
    args = p.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    names = [c for c in args.configs.split(",") if c]
    options = list(optim.OPTIONS) if args.options == "all" else args.options.split(",")

    manifest = {
        "version": 1,
        "block": BLOCK,
        "metric_names": list(optim.METRIC_NAMES),
        "options": list(optim.OPTIONS),
        "state_specs": {
            opt: [{"name": nm, "semantic_dtype": dt} for nm, dt in rows]
            for opt, rows in optim.STATE_SPECS.items()
        },
        "configs": {},
        "artifacts": [],
        "optim": {},
    }

    for name in names:
        cfg = model_lib.CONFIGS[name]
        # β₂ is baked into each artifact; the paper's per-model defaults.
        beta2 = args.beta2 if args.beta2 is not None else 0.95
        oc = optim.OptimConfig(beta2=beta2)
        manifest["configs"][name] = config_manifest(cfg)
        manifest["optim"][name] = {
            "beta1": oc.beta1,
            "beta2": oc.beta2,
            "eps": oc.eps,
            "weight_decay": oc.weight_decay,
            "grad_clip": oc.grad_clip,
        }
        print(f"[{name}] n_params={model_lib.num_params(cfg)} padded={model_lib.padded_len(cfg)}")
        manifest["artifacts"].append(export_eval(cfg, args.out_dir))
        manifest["artifacts"].append(export_grad(cfg, args.out_dir))
        manifest["artifacts"].append(export_predict(cfg, args.out_dir))
        manifest["configs"][name]["init_file"] = export_init(cfg, args.out_dir, args.seed)
        for option in options:
            manifest["artifacts"].append(export_train(cfg, option, oc, args.out_dir))

    def export_variant(cfg_name, beta2, variant_options):
        """β₂-ablation train artifacts (Table 6 / Figs 5-12)."""
        tag = f"b{str(beta2).replace('0.', '')}_"
        cfg = model_lib.CONFIGS[cfg_name]
        oc = optim.OptimConfig(beta2=beta2)
        for option in variant_options:
            entry = export_train(cfg, option, oc, args.out_dir, tag=tag)
            entry["beta2"] = beta2
            manifest["artifacts"].append(entry)

    if args.beta2 is None:
        core = [o for o in ("a", "collage-light", "collage-plus", "dmw", "d")
                if o in options]
        # tiny gets the full strategy set at each β₂ (Fig. 3 compares all
        # baselines at β₂=0.999); tiny2x only needs the Table-6 options.
        if "tiny" in names:
            for beta2 in (0.99, 0.999):
                export_variant("tiny", beta2, options)
        if "tiny2x" in names:
            for beta2 in (0.99, 0.999):
                export_variant("tiny2x", beta2, core)
        # OpenLLaMA-style β₂=0.99 stability study on the small config
        # (Fig. 6): A vs Collage vs D under the unstable β₂.
        if "small" in names:
            export_variant("small", 0.99, [o for o in ("a", "collage-light",
                           "collage-plus", "d") if o in options])

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
