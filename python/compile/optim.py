"""Layer-2 precision-strategy train steps (paper Sec. 5, Table 2).

One train-step function per precision option; each is later lowered once by
``aot.py`` to a self-contained HLO artifact that the Rust coordinator
executes every step — Python never runs at training time.

Strategies (ordered by bytes/parameter, Table 2):

==============  =====================================================
``a``           Option A — pure bf16 parameters + bf16 optimizer states
``collage-light``  Option B — bf16 + MCF (θ, δθ) via the Pallas kernel
``collage-plus``   Option C — B plus MCF second moment (v, δv) and β₂
``dmw``         D⁻ᴹᵂ — bf16 params, fp32 optimizer states, no master wts
``d``           Option D — bf16 + fp32 optimizer states + fp32 master wts
``kahan``       BF16-Kahan baseline (Zamirai et al. 2020)
``sr``          BF16 + stochastic rounding at the parameter update
``fp32``        full fp32 reference ("FP32" curve in Fig. 3)
==============  =====================================================

Every step returns its new state followed by a fixed metrics vector
(see ``METRIC_NAMES``) carrying the paper's diagnostics: loss, grad norm
(Fig. 5/6), parameter/update norms (Fig. 2), **EDQ** (Def. 3.3, Fig. 3
right), and the imprecision/lost-arithmetic percentage (Fig. 3 left).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import model as model_lib
from .kernels import mcf, ref

OPTIONS = ("a", "collage-light", "collage-plus", "dmw", "d", "kahan", "sr", "fp32")

METRIC_NAMES = (
    "loss",
    "grad_norm",       # fp32 global grad norm, pre-clipping
    "param_norm",      # ‖θ_eval‖₂ (MCF options evaluate θ+δθ)  — Fig. 2
    "update_norm",     # ‖Δθ‖₂ (intended update)                — Fig. 2
    "eff_update_norm", # ‖Δθ̂‖₂ (effective update, Eq. 2)
    "edq",             # effective descent quality (Eq. 3)      — Fig. 3
    "lost_frac",       # fraction of params with Δθ≠0 yet unchanged θ
    "clip_coef",       # gradient-clipping coefficient applied
)
NUM_METRICS = len(METRIC_NAMES)


@dataclasses.dataclass(frozen=True)
class OptimConfig:
    """AdamW hyper-parameters shared by every strategy (paper App. E)."""

    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0  # NeMo default global-norm clipping


# State vector names per option, in artifact I/O order.  All are flat
# [padded_len] f32 containers; semantic dtype is recorded for the memory
# model and the manifest.
STATE_SPECS: Dict[str, List[Tuple[str, str]]] = {
    "a": [("theta", "bf16"), ("m", "bf16"), ("v", "bf16")],
    "collage-light": [("theta", "bf16"), ("dtheta_c", "bf16"), ("m", "bf16"), ("v", "bf16")],
    "collage-plus": [
        ("theta", "bf16"),
        ("dtheta_c", "bf16"),
        ("m", "bf16"),
        ("v", "bf16"),
        ("dv", "bf16"),
    ],
    "dmw": [("theta", "bf16"), ("m", "fp32"), ("v", "fp32")],
    "d": [("theta", "bf16"), ("m", "fp32"), ("v", "fp32"), ("mw", "fp32")],
    "kahan": [("theta", "bf16"), ("c", "bf16"), ("m", "bf16"), ("v", "bf16")],
    "sr": [("theta", "bf16"), ("m", "bf16"), ("v", "bf16")],
    "fp32": [("theta", "fp32"), ("m", "fp32"), ("v", "fp32")],
}


def init_state(option: str, flat_theta: jnp.ndarray) -> List[jnp.ndarray]:
    """Zero-initialized optimizer state for ``option`` given initial θ."""
    out = []
    for name, _ in STATE_SPECS[option]:
        if name == "theta":
            out.append(flat_theta)
        elif name == "mw":
            out.append(flat_theta)  # master weights start as fp32 copy of θ
        else:
            out.append(jnp.zeros_like(flat_theta))
    return out


# ---------------------------------------------------------------------------
# Shared pieces.
# ---------------------------------------------------------------------------


def _grad_prep(flat_for_model, tokens, targets, cfg, oc: OptimConfig, compute_dtype):
    """Loss, clipped bf16 grad, and the fp32 grad-norm metric."""
    loss, g32 = model_lib.loss_and_grad(flat_for_model, tokens, targets, cfg, compute_dtype)
    gnorm = jnp.sqrt(jnp.sum(jnp.square(g32)))
    coef = jnp.minimum(1.0, oc.grad_clip / (gnorm + 1e-6))
    return loss, g32 * coef, gnorm, coef


def bias_corrections(oc: OptimConfig, t: int):
    """bc = 1 - βᵗ computed in float64, single-rounded to f32 — the paper's
    high-precision-scalar rule.  The *coordinator* computes these each step
    and feeds them as scalar inputs (so the Rust reference and the HLO
    artifact consume bit-identical values; in-graph `pow` would not be
    reproducible across backends)."""
    import numpy as np

    bc1 = np.float32(1.0 - np.float64(oc.beta1) ** t)
    bc2 = np.float32(1.0 - np.float64(oc.beta2) ** t)
    return bc1, bc2


def _metrics(loss, gnorm, coef, theta_eval_old, theta_eval_new, dtheta):
    """The fixed fp32 metrics vector (names in METRIC_NAMES).

    ``lost_frac`` is measured on the *effective* parameter (the expansion
    sum for MCF strategies, the master weights for option D): an update
    absorbed into δθ is captured, not lost — only a parameter whose
    evaluated value did not move despite a non-zero intended update counts
    (Def. 3.2 applied to the strategy's true state).
    """
    eff = theta_eval_new - theta_eval_old  # Δθ̂ (Eq. 2) in fp32
    un = jnp.sqrt(jnp.sum(jnp.square(dtheta)))
    en = jnp.sqrt(jnp.sum(jnp.square(eff)))
    edq = jnp.sum(dtheta * eff) / jnp.maximum(un, 1e-30)  # Eq. 3
    lost = jnp.mean(
        jnp.logical_and(eff == 0.0, dtheta != 0.0).astype(jnp.float32)
    )
    pn = jnp.sqrt(jnp.sum(jnp.square(theta_eval_new)))
    return jnp.stack([loss, gnorm, pn, un, en, edq, lost, coef])


def _fp32_adamw_delta(theta_ref, g, m, v, bc1, bc2, lr, oc: OptimConfig):
    """Plain fp32 AdamW Δθ (used by options d / dmw / fp32)."""
    m_new = oc.beta1 * m + (1.0 - oc.beta1) * g
    v_new = oc.beta2 * v + (1.0 - oc.beta2) * jnp.square(g)
    m_hat = m_new / bc1
    v_hat = v_new / bc2
    dtheta = -lr * (m_hat / (jnp.sqrt(v_hat) + oc.eps) + oc.weight_decay * theta_ref)
    return dtheta, m_new, v_new


def _pack(oc: OptimConfig, bc1, bc2, lr):
    return ref.pack_scalars(oc.beta1, oc.beta2, bc1, bc2, lr, oc.eps, oc.weight_decay)


# ---------------------------------------------------------------------------
# Per-option train steps.  Uniform signature:
#   step(tokens, targets, lr, bc1, bc2, seed, *state) -> (*new_state, metrics)
# ``bc1``/``bc2`` are the fp32 bias corrections 1-βᵗ supplied by the
# coordinator (see ``bias_corrections``); ``seed`` is a u32 scalar (used
# only by ``sr`` but kept in every signature so the runtime is uniform).
# ---------------------------------------------------------------------------


def make_train_step(option: str, cfg: model_lib.ModelConfig, oc: OptimConfig) -> Callable:
    """Build the jittable train step for ``option``."""
    if option not in OPTIONS:
        raise ValueError(f"unknown option {option!r}; expected one of {OPTIONS}")
    compute_dtype = jnp.float32 if option == "fp32" else jnp.bfloat16

    def step(tokens, targets, lr, bc1, bc2, seed, *state):
        if option == "a":
            theta, m, v = state
            loss, gc, gnorm, coef = _grad_prep(theta, tokens, targets, cfg, oc, compute_dtype)
            g = ref.rnb(gc)
            scal = _pack(oc, bc1, bc2, lr)
            th_new, m_new, v_new, dt = mcf.adamw_a(scal, g, theta, m, v)
            mets = _metrics(loss, gnorm, coef, theta, th_new, dt)
            return th_new, m_new, v_new, mets

        if option == "collage-light":
            theta, dc, m, v = state
            loss, gc, gnorm, coef = _grad_prep(theta, tokens, targets, cfg, oc, compute_dtype)
            g = ref.rnb(gc)
            scal = _pack(oc, bc1, bc2, lr)
            th_new, dc_new, m_new, v_new, dt = mcf.collage_light(scal, g, theta, dc, m, v)
            mets = _metrics(loss, gnorm, coef, theta + dc, th_new + dc_new, dt)
            return th_new, dc_new, m_new, v_new, mets

        if option == "collage-plus":
            theta, dc, m, v, dv = state
            loss, gc, gnorm, coef = _grad_prep(theta, tokens, targets, cfg, oc, compute_dtype)
            g = ref.rnb(gc)
            scal = _pack(oc, bc1, bc2, lr)
            th_new, dc_new, m_new, v_new, dv_new, dt = mcf.collage_plus(
                scal, g, theta, dc, m, v, dv
            )
            mets = _metrics(loss, gnorm, coef, theta + dc, th_new + dc_new, dt)
            return th_new, dc_new, m_new, v_new, dv_new, mets

        if option == "kahan":
            theta, c, m, v = state
            loss, gc, gnorm, coef = _grad_prep(theta, tokens, targets, cfg, oc, compute_dtype)
            g = ref.rnb(gc)
            scal = _pack(oc, bc1, bc2, lr)
            th_new, c_new, m_new, v_new, dt = mcf.kahan(scal, g, theta, c, m, v)
            mets = _metrics(loss, gnorm, coef, theta, th_new, dt)
            return th_new, c_new, m_new, v_new, mets

        if option == "sr":
            theta, m, v = state
            loss, gc, gnorm, coef = _grad_prep(theta, tokens, targets, cfg, oc, compute_dtype)
            g = ref.rnb(gc)
            scal = _pack(oc, bc1, bc2, lr)
            sd = ref.unpack_scalars(scal)
            m_new, v_new = ref.moments_bf16(
                g, m, v, sd["beta1"], sd["one_m_beta1"], sd["b2hi"], sd["one_m_beta2"]
            )
            vh = ref.v_hat_bf16(v_new, sd["bc2"])
            dt = ref.delta_theta(theta, m_new, vh, sd["bc1"], sd["lr"], sd["eps"], sd["wd"])
            # Stochastic rounding of the exact fp32 sum to bf16 (App. B):
            # add a uniform u16 to the low mantissa bits, truncate to bf16.
            exact = theta + dt
            key = jax.random.PRNGKey(seed)
            noise = jnp.bitwise_and(
                jax.random.bits(key, exact.shape, jnp.uint32), jnp.uint32(0xFFFF)
            )
            bits = jax.lax.bitcast_convert_type(exact, jnp.uint32) + noise
            th_new = jax.lax.bitcast_convert_type(
                jnp.bitwise_and(bits, jnp.uint32(0xFFFF0000)), jnp.float32
            )
            # preserve exact zeros (bit trick maps +0 with noise to denormals)
            th_new = jnp.where(exact == 0.0, 0.0, th_new)
            mets = _metrics(loss, gnorm, coef, theta, th_new, dt)
            return th_new, m_new, v_new, mets

        if option == "dmw":
            theta, m, v = state
            loss, gc, gnorm, coef = _grad_prep(theta, tokens, targets, cfg, oc, compute_dtype)
            g = ref.rnb(gc)  # gradients stored bf16 (Table 2)
            dt32, m_new, v_new = _fp32_adamw_delta(theta, g, m, v, bc1, bc2, lr, oc)
            # fp32 optimizer math, but the *storage* is bf16 → the final
            # rounding still loses the small updates (Table 3: D⁻ᴹᵂ ≈ A+).
            th_new = ref.rnb(theta + dt32)
            mets = _metrics(loss, gnorm, coef, theta, th_new, dt32)
            return th_new, m_new, v_new, mets

        if option == "d":
            theta, m, v, mw = state
            loss, gc, gnorm, coef = _grad_prep(theta, tokens, targets, cfg, oc, compute_dtype)
            g = ref.rnb(gc)
            dt32, m_new, v_new = _fp32_adamw_delta(mw, g, m, v, bc1, bc2, lr, oc)
            mw_new = mw + dt32  # fp32 master-weight update: nothing lost
            th_new = ref.rnb(mw_new)  # bf16 working copy for the next fwd/bwd
            mets = _metrics(loss, gnorm, coef, mw, mw_new, dt32)
            return th_new, m_new, v_new, mw_new, mets

        if option == "fp32":
            theta, m, v = state
            loss, g32 = model_lib.loss_and_grad(theta, tokens, targets, cfg, compute_dtype)
            gnorm = jnp.sqrt(jnp.sum(jnp.square(g32)))
            coef = jnp.minimum(1.0, oc.grad_clip / (gnorm + 1e-6))
            g = g32 * coef
            dt32, m_new, v_new = _fp32_adamw_delta(theta, g, m, v, bc1, bc2, lr, oc)
            th_new = theta + dt32
            mets = _metrics(loss, gnorm, coef, theta, th_new, dt32)
            return th_new, m_new, v_new, mets

        raise AssertionError(option)

    return step


def make_eval_step(cfg: model_lib.ModelConfig, compute_dtype=jnp.bfloat16) -> Callable:
    """Validation step: (tokens, targets, θ) -> scalar mean NLL."""

    def step(tokens, targets, theta):
        return model_lib.loss_fn(theta, tokens, targets, cfg, compute_dtype)

    return step


def make_grad_step(cfg: model_lib.ModelConfig, compute_dtype=jnp.bfloat16) -> Callable:
    """Forward+backward only: (tokens, targets, θ) -> (loss, bf16 grad).

    Used by the data-parallel runtime: each worker computes grads on its
    shard; the leader all-reduces and runs the optimizer artifact once.
    """

    def step(tokens, targets, theta):
        loss, g32 = model_lib.loss_and_grad(theta, tokens, targets, cfg, compute_dtype)
        return loss, g32

    return step
