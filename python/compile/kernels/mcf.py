"""Pallas kernels for the Collage MCF optimizer — the paper's compute hot-spot.

Layer-1 of the stack.  Each kernel fuses the *entire* per-element optimizer
update chain of Algorithm 2 (moment EMAs, bias-corrected Δθ, and the
Grow-based parameter update) into a single pass over the flat parameter
vector: one read and one write per state vector per step, which is exactly
the memory-traffic profile that yields the paper's Table-7 speedups.

Kernels are lowered with ``interpret=True`` so the resulting HLO runs on any
PJRT backend (the Rust CPU client); a real-TPU port would keep the same
BlockSpec structure (8×128-aligned elementwise VPU blocks, double-buffered —
see DESIGN.md §L1 real-TPU estimate).

Numerical semantics are inherited from :mod:`ref` — emulated bf16 via
explicit f32→bf16 round after every elementwise op — and pytest enforces
bitwise agreement between each kernel and its oracle.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Flat-vector alignment: 8 sublanes × 128 lanes — the native TPU VPU tile.
# All flat state vectors are padded to a multiple of this.
BLOCK = 1024

# Block size used by the fused kernels.  On a real TPU this would be a
# VMEM-sized tile (e.g. 64-512 KiB per operand) iterated by the grid with
# double buffering; under interpret=True on CPU every grid step costs a
# full interpreter dispatch (§Perf: a 900-block grid made one small-model
# step take 3.5 s), so we use ONE full-vector block — the kernel is purely
# elementwise, and the structure (BlockSpec + grid) stays identical, only
# the tile extent changes for the TPU port (see DESIGN.md §L1).
def _grid_and_block(n: int):
    if n % BLOCK != 0:
        raise ValueError(
            f"flat vector length {n} must be padded to a multiple of {BLOCK}; "
            "see aot.py / model.flatten_params"
        )
    block = int(os.environ.get("COLLAGE_KERNEL_BLOCK", n))
    if n % block != 0:
        raise ValueError(f"block {block} must divide padded length {n}")
    return (n // block,), block


def _scal_spec():
    """BlockSpec broadcasting the scalar vector to every grid step."""
    return pl.BlockSpec((ref.NUM_SCALARS,), lambda i: (0,))


def _vec_spec(block):
    """BlockSpec carving the flat state vectors into block-sized tiles."""
    return pl.BlockSpec((block,), lambda i: (i,))


# ---------------------------------------------------------------------------
# Fused optimizer-step kernels.
# ---------------------------------------------------------------------------


def _adamw_a_kernel(scal_ref, g_ref, th_ref, m_ref, v_ref, th_o, m_o, v_o, dt_o):
    scal = ref.unpack_scalars(scal_ref[...])
    th, dc_m, dc_v, dt = ref.adamw_step_a(g_ref[...], th_ref[...], m_ref[...], v_ref[...], scal)
    th_o[...], m_o[...], v_o[...], dt_o[...] = th, dc_m, dc_v, dt


def adamw_a(scal, g, theta, m, v):
    """Option A — pure bf16 AdamW, fused. Returns (θ', m', v', Δθ)."""
    n = g.shape[0]
    grid, block = _grid_and_block(n)
    out = jax.ShapeDtypeStruct((n,), jnp.float32)
    return pl.pallas_call(
        _adamw_a_kernel,
        grid=grid,
        in_specs=[_scal_spec()] + [_vec_spec(block)] * 4,
        out_specs=[_vec_spec(block)] * 4,
        out_shape=[out] * 4,
        interpret=True,
    )(scal, g, theta, m, v)


def _collage_light_kernel(
    scal_ref, g_ref, th_ref, dc_ref, m_ref, v_ref, th_o, dc_o, m_o, v_o, dt_o
):
    scal = ref.unpack_scalars(scal_ref[...])
    th, dc, m, v, dt = ref.adamw_step_light(
        g_ref[...], th_ref[...], dc_ref[...], m_ref[...], v_ref[...], scal
    )
    th_o[...], dc_o[...], m_o[...], v_o[...], dt_o[...] = th, dc, m, v, dt


def collage_light(scal, g, theta, dtheta_c, m, v):
    """Option B — Collage-light: MCF (θ, δθ) via Grow. Returns (θ', δθ', m', v', Δθ)."""
    n = g.shape[0]
    grid, block = _grid_and_block(n)
    out = jax.ShapeDtypeStruct((n,), jnp.float32)
    return pl.pallas_call(
        _collage_light_kernel,
        grid=grid,
        in_specs=[_scal_spec()] + [_vec_spec(block)] * 5,
        out_specs=[_vec_spec(block)] * 5,
        out_shape=[out] * 5,
        interpret=True,
    )(scal, g, theta, dtheta_c, m, v)


def _collage_plus_kernel(
    scal_ref, g_ref, th_ref, dc_ref, m_ref, v_ref, dv_ref,
    th_o, dc_o, m_o, v_o, dv_o, dt_o,
):
    scal = ref.unpack_scalars(scal_ref[...])
    th, dc, m, v, dv, dt = ref.adamw_step_plus(
        g_ref[...], th_ref[...], dc_ref[...], m_ref[...], v_ref[...], dv_ref[...], scal
    )
    th_o[...], dc_o[...], m_o[...], v_o[...], dv_o[...], dt_o[...] = th, dc, m, v, dv, dt


def collage_plus(scal, g, theta, dtheta_c, m, v, dv):
    """Option C — Collage-plus: MCF parameters *and* MCF second moment.

    Returns (θ', δθ', m', v', δv', Δθ).
    """
    n = g.shape[0]
    grid, block = _grid_and_block(n)
    out = jax.ShapeDtypeStruct((n,), jnp.float32)
    return pl.pallas_call(
        _collage_plus_kernel,
        grid=grid,
        in_specs=[_scal_spec()] + [_vec_spec(block)] * 6,
        out_specs=[_vec_spec(block)] * 6,
        out_shape=[out] * 6,
        interpret=True,
    )(scal, g, theta, dtheta_c, m, v, dv)


def _kahan_kernel(scal_ref, g_ref, th_ref, c_ref, m_ref, v_ref, th_o, c_o, m_o, v_o, dt_o):
    scal = ref.unpack_scalars(scal_ref[...])
    th, c, m, v, dt = ref.adamw_step_kahan(
        g_ref[...], th_ref[...], c_ref[...], m_ref[...], v_ref[...], scal
    )
    th_o[...], c_o[...], m_o[...], v_o[...], dt_o[...] = th, c, m, v, dt


def kahan(scal, g, theta, c, m, v):
    """Kahan-compensated bf16 AdamW baseline. Returns (θ', c', m', v', Δθ)."""
    n = g.shape[0]
    grid, block = _grid_and_block(n)
    out = jax.ShapeDtypeStruct((n,), jnp.float32)
    return pl.pallas_call(
        _kahan_kernel,
        grid=grid,
        in_specs=[_scal_spec()] + [_vec_spec(block)] * 5,
        out_specs=[_vec_spec(block)] * 5,
        out_shape=[out] * 5,
        interpret=True,
    )(scal, g, theta, c, m, v)


# ---------------------------------------------------------------------------
# Primitive MCF kernels — exposed for tests, benches and downstream reuse.
# Whole-array single-block kernels: accept any shape/dtype=f32.
# ---------------------------------------------------------------------------


def _binary_expansion_call(kernel_body, a, b):
    out = jax.ShapeDtypeStruct(a.shape, jnp.float32)
    return pl.pallas_call(kernel_body, out_shape=(out, out), interpret=True)(a, b)


def two_sum(a, b):
    """Pallas TwoSum: exact a + b = (x, y) for arbitrary bf16 operands."""

    def body(a_ref, b_ref, x_o, y_o):
        x_o[...], y_o[...] = ref.two_sum(a_ref[...], b_ref[...])

    return _binary_expansion_call(body, a, b)


def fast2sum(a, b):
    """Pallas Fast2Sum (requires |a| >= |b| elementwise)."""

    def body(a_ref, b_ref, x_o, y_o):
        x_o[...], y_o[...] = ref.fast2sum(a_ref[...], b_ref[...])

    return _binary_expansion_call(body, a, b)


def two_prod(a, b):
    """Pallas TwoProdFMA: exact a * b = (x, e)."""

    def body(a_ref, b_ref, x_o, y_o):
        x_o[...], y_o[...] = ref.two_prod(a_ref[...], b_ref[...])

    return _binary_expansion_call(body, a, b)


def grow(x, y, a):
    """Pallas Grow: expansion (x, y) + float a -> expansion (u, v)."""

    def body(x_ref, y_ref, a_ref, u_o, v_o):
        u_o[...], v_o[...] = ref.grow(x_ref[...], y_ref[...], a_ref[...])

    out = jax.ShapeDtypeStruct(x.shape, jnp.float32)
    return pl.pallas_call(body, out_shape=(out, out), interpret=True)(x, y, a)


def scaling(a1, a2, v):
    """Pallas Scaling: expansion (a1, a2) times float v -> expansion."""

    def body(a1_ref, a2_ref, v_ref, x_o, e_o):
        x_o[...], e_o[...] = ref.scaling(a1_ref[...], a2_ref[...], v_ref[...])

    out = jax.ShapeDtypeStruct(a1.shape, jnp.float32)
    return pl.pallas_call(body, out_shape=(out, out), interpret=True)(a1, a2, v)


def mul(a1, a2, b1, b2):
    """Pallas Mul: expansion × expansion -> expansion."""

    def body(a1_ref, a2_ref, b1_ref, b2_ref, x_o, e_o):
        x_o[...], e_o[...] = ref.mul(a1_ref[...], a2_ref[...], b1_ref[...], b2_ref[...])

    out = jax.ShapeDtypeStruct(a1.shape, jnp.float32)
    return pl.pallas_call(body, out_shape=(out, out), interpret=True)(a1, a2, b1, b2)


# Registry used by optim.py / aot.py to pick the fused kernel per option.
FUSED = {
    "a": adamw_a,
    "collage-light": collage_light,
    "collage-plus": collage_plus,
    "kahan": kahan,
}

__all__ = [
    "BLOCK",
    "adamw_a",
    "collage_light",
    "collage_plus",
    "kahan",
    "two_sum",
    "fast2sum",
    "two_prod",
    "grow",
    "scaling",
    "mul",
    "FUSED",
]
