"""Pure-jnp oracle for the Collage MCF kernels.

This module is the single source of truth for the *semantics* of emulated
bfloat16 arithmetic and the multi-component-float (MCF) expansion algebra of
the paper (Priest 1991; Dekker 1971; Yu et al. 2022 "MCTensor"):

  * every emulated-bf16 value is stored in an f32 container (every bf16 is
    exactly an f32),
  * every bf16 operation is realized as the exact f32 operation followed by
    an explicit round-to-nearest-even cast to bf16 (``rnb``).

This is bit-exact bf16 arithmetic: rounding an IEEE-correct f32 result to
bf16 equals direct bf16 rounding because f32 carries 24 significand bits
>= 2*8+2 (the classic "double rounding is innocuous when p2 >= 2*p1+2"
theorem, Figueroa 1995).  The Rust reference implementation
(``rust/src/numerics``) mirrors these exact semantics so that the two stacks
can be cross-checked bitwise.

The Pallas kernels in ``mcf.py`` must match this oracle *exactly* (bitwise);
pytest enforces that.
"""

from __future__ import annotations

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Emulated bf16 primitive: round-to-nearest-even into a bfloat16 container.
# ---------------------------------------------------------------------------


def rnb(x):
    """Round an f32 array to bf16 (RN-even), returned in an f32 container."""
    return x.astype(jnp.bfloat16).astype(jnp.float32)


def badd(a, b):
    """bf16 addition: F_bf16(a + b) for bf16-representable f32 inputs."""
    return rnb(a + b)


def bsub(a, b):
    """bf16 subtraction: F_bf16(a - b)."""
    return rnb(a - b)


def bmul(a, b):
    """bf16 multiplication: F_bf16(a * b)."""
    return rnb(a * b)


def bdiv(a, b):
    """bf16 division: F_bf16(a / b)."""
    return rnb(a / b)


def bsqrt(a):
    """bf16 square root: F_bf16(sqrt(a))."""
    return rnb(jnp.sqrt(a))


# ---------------------------------------------------------------------------
# MCF expansion primitives (paper Sec. 4.1 / Appendix C).
# All inputs/outputs are bf16-representable f32 arrays.
# ---------------------------------------------------------------------------


def two_sum(a, b):
    """TwoSum (Alg. 2): exact a + b = x + y for any floats, no ordering."""
    x = badd(a, b)
    b_virtual = bsub(x, a)
    a_virtual = bsub(x, b_virtual)
    b_roundoff = bsub(b, b_virtual)
    a_roundoff = bsub(a, a_virtual)
    y = badd(a_roundoff, b_roundoff)
    return x, y


def fast2sum(a, b):
    """Fast2Sum (Dekker 1971, Thm 4.1): requires |a| >= |b|.

    Produces (x, y) with a + b = x + y exactly and |y| <= ulp(x)/2.
    """
    x = badd(a, b)
    y = bsub(b, bsub(x, a))
    return x, y


def two_prod(a, b):
    """TwoProdFMA (Alg. 5): exact a * b = x + e.

    The product of two bf16 values (8-bit significands) has at most 16
    significand bits and is exactly representable in f32, so the error term
    ``e = f32(a)*f32(b) - f32(x)`` is computed exactly; this is the standard
    TwoProdFMA realization (see DESIGN.md §TwoProdFMA note).
    """
    x = bmul(a, b)
    e = rnb(a * b - x)
    return x, e


def grow(x, y, a):
    """Grow (Alg. 1): add float ``a`` to expansion ``(x, y)``, |x| >= |a|.

    Returns a length-2 expansion (u, v) with u + v ~= x + y + a where the
    dominant rounding error of the x + a addition is captured exactly.
    """
    u, v = fast2sum(x, a)
    u, v = fast2sum(u, badd(y, v))
    return u, v


def scaling(a1, a2, v):
    """Scaling (Alg. 6): multiply expansion (a1, a2) by float v."""
    x, e = two_prod(a1, v)
    e = badd(bmul(a2, v), e)
    return fast2sum(x, e)


def mul(a1, a2, b1, b2):
    """Mul (Alg. 7): multiply two length-2 expansions -> length-2 expansion."""
    x, e = two_prod(a1, b1)
    e = badd(e, badd(bmul(a1, b2), bmul(a2, b1)))
    return fast2sum(x, e)


def split_scalar(value: float):
    """Exact length-2 bf16 expansion of a python float (paper Table 1).

    hi = RN_bf16(value), lo = RN_bf16(value - hi).  For the β₂ values used in
    practice (0.999, 0.99, 0.98, 0.95) the expansion is exact.
    """
    import ml_dtypes
    import numpy as np

    hi = np.float32(np.asarray(value, dtype=np.float32).astype(ml_dtypes.bfloat16))
    lo = np.float32(
        np.asarray(np.float64(value) - np.float64(hi), dtype=np.float32).astype(
            ml_dtypes.bfloat16
        )
    )
    return float(hi), float(lo)


# ---------------------------------------------------------------------------
# Reference optimizer updates (elementwise; flat f32 arrays in/out).
# These mirror Algorithm 2 of the paper; the Pallas kernels fuse the same
# op-chain and must match bitwise.
#
# Scalar arguments (beta1, one_m_beta1, ..., bc1, bc2, lr, eps, wd) are f32
# *high-precision* scalars per the paper's rule of thumb ("do as many scalar
# computations in high precision as possible"); the elementwise tensor math
# is emulated bf16.
# ---------------------------------------------------------------------------


def moments_bf16(g, m, v, beta1, one_m_beta1, beta2, one_m_beta2):
    """Standard bf16 AdamW moment updates (options A, B, kahan, sr).

    m' = F(F(β₁ ⊙ m) ⊕ F((1-β₁) ⊙ g)) ;  v' analogous with g².
    The scalars are f32; each elementwise op rounds to bf16.
    """
    m_new = badd(bmul(m, beta1), bmul(g, one_m_beta1))
    g2 = bmul(g, g)
    v_new = badd(bmul(v, beta2), bmul(g2, one_m_beta2))
    return m_new, v_new


def moments_plus(g, m, v, dv, beta1, one_m_beta1, b2hi, b2lo, one_m_beta2):
    """Collage-plus moment updates (Alg. 2 line 9).

    m is standard bf16; the second moment is a length-2 expansion (v, δv)
    multiplied by the β₂ expansion (b2hi, b2lo) via Mul, then Grown by the
    float (1-β₂)·g².
    """
    m_new = badd(bmul(m, beta1), bmul(g, one_m_beta1))
    g2 = bmul(g, g)
    incr = bmul(g2, one_m_beta2)
    vx, ve = mul(v, dv, b2hi, b2lo)
    v_new, dv_new = grow(vx, ve, incr)
    return m_new, v_new, dv_new


def delta_theta(theta, m_new, v_eval_hat, bc1, lr, eps, wd):
    """Aggregated update Δθ (Alg. 2 line 12), emulated bf16.

    Δθ = -α( m̂ / (sqrt(v̂) + ε) + λθ ) with m̂ = m/bc1 (bc1 = 1-β₁ᵗ in f32)
    and v̂ supplied by the caller (option-dependent, already bias-corrected
    in f32 per the paper's scalar rule).  Decoupled weight decay sits inside
    Δθ (the paper's fix for the weight-decay lost-arithmetic issue, App. D).
    """
    m_hat = rnb(m_new / bc1)
    denom = badd(bsqrt(v_eval_hat), eps)
    t1 = bdiv(m_hat, denom)
    t2 = bmul(theta, wd)
    return rnb(-lr * badd(t1, t2))


def v_hat_bf16(v_new, bc2):
    """Bias-corrected second moment for single-float v (f32 scalar divide)."""
    return rnb(v_new / bc2)


def v_hat_plus(v_new, dv_new, bc2):
    """Bias-corrected second moment for the (v, δv) expansion.

    The expansion is evaluated in f32 (exact: hi+lo fits easily) and divided
    by the f32 scalar bc2 = 1-β₂ᵗ, then rounded once — the "scalar math in
    high precision" rule.
    """
    return rnb((v_new + dv_new) / bc2)


def apply_update_bf16(theta, dtheta):
    """Option-A parameter update: θ' = F(θ ⊕ Δθ) — where arithmetic is lost."""
    return badd(theta, dtheta)


def apply_update_light(theta, dtheta_c, dtheta):
    """Collage-light/plus parameter update: (θ, δθ) ← Grow((θ, δθ), Δθ)."""
    return grow(theta, dtheta_c, dtheta)


def apply_update_kahan(theta, c, dtheta):
    """Kahan-compensated update (Zamirai et al. 2020; App. B).

    Δθ' = F(Δθ ⊕ c); θ' = F(θ ⊕ Δθ'); c' = F(Δθ' ⊖ F(θ' ⊖ θ)).
    """
    d = badd(dtheta, c)
    theta_new = badd(theta, d)
    c_new = bsub(d, bsub(theta_new, theta))
    return theta_new, c_new


def adamw_step_a(g, theta, m, v, scal):
    """Full Option-A (pure bf16) fused step. ``scal`` is the scalar dict."""
    m_new, v_new = moments_bf16(
        g, m, v, scal["beta1"], scal["one_m_beta1"], scal["b2hi"], scal["one_m_beta2"]
    )
    vh = v_hat_bf16(v_new, scal["bc2"])
    dt = delta_theta(theta, m_new, vh, scal["bc1"], scal["lr"], scal["eps"], scal["wd"])
    theta_new = apply_update_bf16(theta, dt)
    return theta_new, m_new, v_new, dt


def adamw_step_light(g, theta, dtheta_c, m, v, scal):
    """Full Collage-light fused step (MCF parameters only)."""
    m_new, v_new = moments_bf16(
        g, m, v, scal["beta1"], scal["one_m_beta1"], scal["b2hi"], scal["one_m_beta2"]
    )
    vh = v_hat_bf16(v_new, scal["bc2"])
    dt = delta_theta(theta, m_new, vh, scal["bc1"], scal["lr"], scal["eps"], scal["wd"])
    theta_new, dc_new = apply_update_light(theta, dtheta_c, dt)
    return theta_new, dc_new, m_new, v_new, dt


def adamw_step_plus(g, theta, dtheta_c, m, v, dv, scal):
    """Full Collage-plus fused step (MCF parameters + MCF second moment)."""
    m_new, v_new, dv_new = moments_plus(
        g,
        m,
        v,
        dv,
        scal["beta1"],
        scal["one_m_beta1"],
        scal["b2hi"],
        scal["b2lo"],
        scal["one_m_beta2"],
    )
    vh = v_hat_plus(v_new, dv_new, scal["bc2"])
    dt = delta_theta(theta, m_new, vh, scal["bc1"], scal["lr"], scal["eps"], scal["wd"])
    theta_new, dc_new = apply_update_light(theta, dtheta_c, dt)
    return theta_new, dc_new, m_new, v_new, dv_new, dt


def adamw_step_kahan(g, theta, c, m, v, scal):
    """Full Kahan-compensated bf16 step (baseline; App. B/D)."""
    m_new, v_new = moments_bf16(
        g, m, v, scal["beta1"], scal["one_m_beta1"], scal["b2hi"], scal["one_m_beta2"]
    )
    vh = v_hat_bf16(v_new, scal["bc2"])
    dt = delta_theta(theta, m_new, vh, scal["bc1"], scal["lr"], scal["eps"], scal["wd"])
    theta_new, c_new = apply_update_kahan(theta, c, dt)
    return theta_new, c_new, m_new, v_new, dt


# ---------------------------------------------------------------------------
# Scalar packing shared by oracle, Pallas kernels and the L2 optimizer.
# ---------------------------------------------------------------------------

SCALAR_NAMES = (
    "beta1",
    "one_m_beta1",
    "b2hi",
    "b2lo",
    "one_m_beta2",
    "bc1",
    "bc2",
    "lr",
    "eps",
    "wd",
)

NUM_SCALARS = len(SCALAR_NAMES)


def pack_scalars(beta1, beta2, bc1, bc2, lr, eps, wd):
    """Build the f32 scalar vector fed to the fused kernels.

    β₁, (1-β₁) are f32 scalars; β₂ is carried as its exact bf16 expansion
    (b2hi, b2lo) — Table 1 of the paper — while (1-β₂) is the exact f32
    scalar (the paper's rule: scalar math in high precision).
    bc1/bc2 = 1-βᵗ bias corrections, computed in f32 by the caller
    (possibly traced); lr likewise.
    """
    beta2_f = jnp.asarray(beta2, jnp.float32)
    b2hi = beta2_f.astype(jnp.bfloat16).astype(jnp.float32)
    b2lo = (beta2_f - b2hi).astype(jnp.bfloat16).astype(jnp.float32)
    beta1_f = jnp.asarray(beta1, jnp.float32)
    vals = [
        beta1_f,
        jnp.float32(1.0) - beta1_f,
        b2hi,
        b2lo,
        jnp.float32(1.0) - beta2_f,
        jnp.asarray(bc1, jnp.float32),
        jnp.asarray(bc2, jnp.float32),
        jnp.asarray(lr, jnp.float32),
        jnp.float32(eps),
        jnp.float32(wd),
    ]
    return jnp.stack([jnp.asarray(x, jnp.float32) for x in vals])


def unpack_scalars(vec):
    """Inverse of :func:`pack_scalars`: scalar vector -> named dict."""
    return {name: vec[i] for i, name in enumerate(SCALAR_NAMES)}
