"""L2 train-step tests: every precision strategy's step function — state
arity, metric semantics, EDQ ordering, β₂ pathology, SR statistics."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model as M
from compile import optim as O

CFG = M.CONFIGS["tiny"]
RNG = np.random.default_rng(99)


def batch(seed=42):
    """Order-independent: a fresh generator per call (pytest may run tests
    in any order; a shared stream would couple test data to ordering)."""
    rng = np.random.default_rng(seed)
    tok = rng.integers(0, CFG.vocab, (CFG.micro_batch, CFG.seq_len)).astype(np.int32)
    tgt = rng.integers(0, CFG.vocab, (CFG.micro_batch, CFG.seq_len)).astype(np.int32)
    return jnp.asarray(tok), jnp.asarray(tgt)


@pytest.fixture(scope="module")
def flat():
    return M.init_params(0, CFG)


@pytest.mark.parametrize("option", O.OPTIONS)
def test_step_runs_and_preserves_arity(option, flat):
    oc = O.OptimConfig()
    step = jax.jit(O.make_train_step(option, CFG, oc))
    state = O.init_state(option, flat)
    tok, tgt = batch()
    bc1, bc2 = O.bias_corrections(oc, 1)
    outs = step(tok, tgt, jnp.float32(1e-3), bc1, bc2, jnp.uint32(0), *state)
    assert len(outs) == len(state) + 1
    mets = np.asarray(outs[-1])
    assert mets.shape == (O.NUM_METRICS,)
    assert np.isfinite(mets).all()
    names = dict(zip(O.METRIC_NAMES, mets))
    assert 3.0 < names["loss"] < 8.0
    assert names["grad_norm"] > 0
    assert 0 <= names["lost_frac"] <= 1
    # every state output stays bf16-representable (fp32 options excepted)
    for (name, dtype), vec in zip(O.STATE_SPECS[option], outs[:-1]):
        if dtype == "bf16":
            v = np.asarray(vec)
            rt = np.asarray(jnp.asarray(v).astype(jnp.bfloat16).astype(jnp.float32))
            np.testing.assert_array_equal(v, rt, err_msg=f"{option}:{name}")


@pytest.mark.parametrize("option", ["a", "collage-light", "collage-plus", "d"])
def test_multi_step_loss_decreases(option, flat):
    oc = O.OptimConfig()
    step = jax.jit(O.make_train_step(option, CFG, oc))
    state = list(O.init_state(option, flat))
    tok, tgt = batch()  # overfit one batch
    losses = []
    for t in range(1, 31):
        bc1, bc2 = O.bias_corrections(oc, t)
        outs = step(tok, tgt, jnp.float32(2e-3), bc1, bc2, jnp.uint32(t), *state)
        state = list(outs[:-1])
        losses.append(float(outs[-1][0]))
    assert losses[-1] < losses[0] - 0.5, f"{option}: {losses[0]:.3f} -> {losses[-1]:.3f}"


def test_edq_ordering_beta2_999(flat):
    """After enough steps at β₂=0.999: EDQ(plus) ≥ EDQ(light) > EDQ(A),
    and option D is lossless — the Fig. 3-right ordering."""
    oc = O.OptimConfig(beta2=0.999)
    tok, tgt = batch()
    ratios = {}
    lost = {}
    for option in ["a", "collage-light", "collage-plus", "d"]:
        step = jax.jit(O.make_train_step(option, CFG, oc))
        state = list(O.init_state(option, flat))
        for t in range(1, 41):
            bc1, bc2 = O.bias_corrections(oc, t)
            outs = step(tok, tgt, jnp.float32(1e-3), bc1, bc2, jnp.uint32(t), *state)
            state = list(outs[:-1])
        mets = dict(zip(O.METRIC_NAMES, np.asarray(outs[-1])))
        ratios[option] = mets["edq"] / max(mets["update_norm"], 1e-30)
        lost[option] = mets["lost_frac"]
    # Short-horizon margins: the quality gap needs thousands of steps to
    # open (Fig. 3 runs 28k), but the EDQ separation is visible at once.
    assert abs(ratios["d"] - 1.0) < 1e-3
    assert ratios["collage-plus"] > 0.9999, ratios
    assert ratios["collage-light"] > 0.9999, ratios
    assert ratios["a"] < 0.9995, ratios
    assert lost["a"] > lost["collage-plus"], lost


def test_sr_moves_parameters_in_expectation(flat):
    """SR escapes lost arithmetic statistically (different seeds differ)."""
    oc = O.OptimConfig()
    step = jax.jit(O.make_train_step("sr", CFG, oc))
    tok, tgt = batch()
    state = O.init_state("sr", flat)
    bc1, bc2 = O.bias_corrections(oc, 1)
    o1 = step(tok, tgt, jnp.float32(1e-3), bc1, bc2, jnp.uint32(1), *state)
    o2 = step(tok, tgt, jnp.float32(1e-3), bc1, bc2, jnp.uint32(2), *state)
    th1, th2 = np.asarray(o1[0]), np.asarray(o2[0])
    assert not np.array_equal(th1, th2), "SR must depend on the seed"
    # SR outputs remain bf16-representable
    rt = np.asarray(jnp.asarray(th1).astype(jnp.bfloat16).astype(jnp.float32))
    np.testing.assert_array_equal(th1, rt)


def test_option_d_master_weights_track_fp32(flat):
    """Option D's MW carries more information than its bf16 θ."""
    oc = O.OptimConfig()
    step = jax.jit(O.make_train_step("d", CFG, oc))
    state = list(O.init_state("d", flat))
    tok, tgt = batch()
    for t in range(1, 11):
        bc1, bc2 = O.bias_corrections(oc, t)
        outs = step(tok, tgt, jnp.float32(1e-4), bc1, bc2, jnp.uint32(t), *state)
        state = list(outs[:-1])
    theta, mw = np.asarray(state[0]), np.asarray(state[3])
    # θ is the bf16 rounding of MW
    rt = np.asarray(jnp.asarray(mw).astype(jnp.bfloat16).astype(jnp.float32))
    np.testing.assert_array_equal(theta, rt)
    assert not np.array_equal(theta, mw)


def test_grad_step_matches_train_loss(flat):
    """The DP grad artifact's loss equals the fused step's reported loss."""
    oc = O.OptimConfig()
    tok, tgt = batch()
    gstep = jax.jit(O.make_grad_step(CFG))
    loss_g, _ = gstep(tok, tgt, flat)
    tstep = jax.jit(O.make_train_step("a", CFG, oc))
    bc1, bc2 = O.bias_corrections(oc, 1)
    outs = tstep(tok, tgt, jnp.float32(1e-3), bc1, bc2, jnp.uint32(0),
                 *O.init_state("a", flat))
    loss_t = np.asarray(outs[-1])[0]
    np.testing.assert_allclose(float(loss_g), float(loss_t), rtol=1e-4)


def test_eval_step_matches_loss_fn(flat):
    tok, tgt = batch()
    estep = jax.jit(O.make_eval_step(CFG))
    l1 = float(estep(tok, tgt, flat))
    l2 = float(M.loss_fn(flat, tok, tgt, CFG))
    # jit vs eager differ by fusion order in the fp32 reductions
    np.testing.assert_allclose(l1, l2, rtol=1e-4)


def test_weight_decay_lost_in_naive_form():
    """App. D: θ ← (1-αλ)θ is a no-op in bf16 for αλ = 1.2e-5."""
    theta = jnp.asarray([1.0, -2.0, 0.5], jnp.float32)
    alpha_lambda = jnp.float32(1.2e-5)
    naive = (theta * (1.0 - alpha_lambda)).astype(jnp.bfloat16).astype(jnp.float32)
    np.testing.assert_array_equal(np.asarray(naive), np.asarray(theta))
