"""AOT exporter tests: HLO-text lowering round-trips, manifest coherence,
and the bias-correction contract shared with the Rust coordinator."""

import json
import os

import numpy as np
import pytest

import jax.numpy as jnp

from compile import aot, model as M, optim as O


def test_to_hlo_text_produces_parseable_module():
    import jax

    cfg = M.CONFIGS["tiny"]
    step = O.make_eval_step(cfg)
    lowered = jax.jit(step, keep_unused=True).lower(
        jax.ShapeDtypeStruct((cfg.micro_batch, cfg.seq_len), jnp.int32),
        jax.ShapeDtypeStruct((cfg.micro_batch, cfg.seq_len), jnp.int32),
        jax.ShapeDtypeStruct((M.padded_len(cfg),), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:80]
    # the interchange contract: tuple-rooted entry computation
    assert "ROOT" in text and "tuple" in text.lower()


def test_export_eval_and_init_roundtrip(tmp_path):
    cfg = M.CONFIGS["tiny"]
    entry = aot.export_eval(cfg, str(tmp_path))
    path = tmp_path / entry["file"]
    assert path.exists()
    import hashlib

    assert entry["sha256"] == hashlib.sha256(path.read_bytes()).hexdigest()
    fname = aot.export_init(cfg, str(tmp_path), seed=7)
    flat = np.load(tmp_path / fname)
    assert flat.shape == (M.padded_len(cfg),)
    assert flat.dtype == np.float32
    # bf16-representable boundary invariant
    rt = np.asarray(jnp.asarray(flat).astype(jnp.bfloat16).astype(jnp.float32))
    np.testing.assert_array_equal(flat, rt)


def test_export_train_manifest_contract(tmp_path):
    cfg = M.CONFIGS["tiny"]
    oc = O.OptimConfig(beta2=0.99)
    entry = aot.export_train(cfg, "collage-plus", oc, str(tmp_path), tag="t_")
    assert entry["file"] == "tiny_t_collage-plus_train.hlo.txt"
    input_names = [i["name"] for i in entry["inputs"]]
    assert input_names[:6] == ["tokens", "targets", "lr", "bc1", "bc2", "seed"]
    assert input_names[6:] == [n for n, _ in O.STATE_SPECS["collage-plus"]]
    output_names = [o["name"] for o in entry["outputs"]]
    assert output_names[-1] == "metrics"
    assert entry["metrics"] == list(O.METRIC_NAMES)


def test_config_manifest_param_table(tmp_path):
    cfg = M.CONFIGS["tiny"]
    man = aot.config_manifest(cfg)
    assert man["n_params"] == M.num_params(cfg)
    rows = man["param_table"]
    assert rows[0]["name"] == "embed" and rows[0]["offset"] == 0
    last = rows[-1]
    assert last["offset"] + int(np.prod(last["shape"])) == man["n_params"]
    json.dumps(man)  # must be JSON-serializable


def test_bias_corrections_contract():
    """Must equal the Rust coordinator's (1 - β^t in f64) -> f32."""
    oc = O.OptimConfig(beta2=0.999)
    bc1, bc2 = O.bias_corrections(oc, 1)
    assert bc1 == np.float32(1.0 - 0.9)
    assert bc2 == np.float32(1.0 - 0.999)
    bc1_10, bc2_10 = O.bias_corrections(oc, 10)
    assert bc1_10 == np.float32(1.0 - np.float64(0.9) ** 10)
    assert 0 < bc2_10 < 0.01
