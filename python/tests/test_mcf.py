"""L1 correctness: Pallas MCF kernels vs the pure-jnp oracle — the core
correctness signal of the compile path.

Every kernel must match `ref.py` **bitwise** (they share semantics by
construction; this guards against Pallas lowering/interpret divergence),
and the oracle itself must satisfy the exactness theorems of the paper
(Fast2Sum/TwoSum/TwoProd exact-sum properties, Thm 4.1 bounds).

Hypothesis sweeps shapes, dtypes of the scalar schedule, and magnitude
regimes (the corners where rounding bugs live).
"""

import numpy as np
import pytest

import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import mcf, ref

RNG = np.random.default_rng(20240710)


def bf16(x):
    return np.asarray(jnp.asarray(x, jnp.float32).astype(jnp.bfloat16).astype(jnp.float32))


def interesting_bf16(shape, scale_pow=0, rng=RNG):
    """bf16-representable values across magnitude regimes."""
    x = rng.normal(size=shape).astype(np.float32) * (10.0**scale_pow)
    return bf16(x)


def assert_bitwise(a, b, msg=""):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    assert a.shape == b.shape, f"{msg}: shape {a.shape} vs {b.shape}"
    ok = np.array_equal(a.view(np.uint32), b.view(np.uint32))
    if not ok:
        i = np.argmax(a.view(np.uint32) != b.view(np.uint32))
        raise AssertionError(f"{msg}: first mismatch at {i}: {a.flat[i]!r} vs {b.flat[i]!r}")


# ---------------------------------------------------------------------------
# Primitive kernels vs oracle (bitwise).
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 16),
    cols=st.integers(1, 64),
    sa=st.integers(-6, 6),
    sb=st.integers(-6, 6),
)
def test_two_sum_kernel_matches_ref(rows, cols, sa, sb):
    a = jnp.asarray(interesting_bf16((rows, cols), sa))
    b = jnp.asarray(interesting_bf16((rows, cols), sb))
    kx, ky = mcf.two_sum(a, b)
    rx, ry = ref.two_sum(a, b)
    assert_bitwise(kx, rx, "two_sum.x")
    assert_bitwise(ky, ry, "two_sum.y")


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 256), sa=st.integers(-4, 4))
def test_fast2sum_kernel_matches_ref(n, sa):
    hi = jnp.asarray(interesting_bf16((n,), sa))
    lo = jnp.asarray(bf16(np.asarray(hi) * 1e-3))
    kx, ky = mcf.fast2sum(hi, lo)
    rx, ry = ref.fast2sum(hi, lo)
    assert_bitwise(kx, rx)
    assert_bitwise(ky, ry)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 128), sa=st.integers(-3, 3), sb=st.integers(-3, 3))
def test_two_prod_kernel_matches_ref(n, sa, sb):
    a = jnp.asarray(interesting_bf16((n,), sa))
    b = jnp.asarray(interesting_bf16((n,), sb))
    kx, ke = mcf.two_prod(a, b)
    rx, re = ref.two_prod(a, b)
    assert_bitwise(kx, rx)
    assert_bitwise(ke, re)


def test_grow_mul_scaling_match_ref():
    n = 512
    x = jnp.asarray(interesting_bf16((n,), 1))
    y = jnp.asarray(bf16(np.asarray(x) * 1e-3))
    a = jnp.asarray(bf16(np.asarray(x) * 0.1))
    for k_out, r_out in zip(mcf.grow(x, y, a), ref.grow(x, y, a)):
        assert_bitwise(k_out, r_out, "grow")
    b1 = jnp.asarray(interesting_bf16((n,), 0))
    b2 = jnp.asarray(bf16(np.asarray(b1) * 1e-3))
    for k_out, r_out in zip(mcf.mul(x, y, b1, b2), ref.mul(x, y, b1, b2)):
        assert_bitwise(k_out, r_out, "mul")
    v = jnp.asarray(interesting_bf16((n,), 0))
    for k_out, r_out in zip(mcf.scaling(x, y, v), ref.scaling(x, y, v)):
        assert_bitwise(k_out, r_out, "scaling")


# ---------------------------------------------------------------------------
# Exactness theorems on the oracle (f64 verification).
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(sa=st.integers(-8, 8), sb=st.integers(-8, 8))
def test_two_sum_exact_in_f64(sa, sb):
    a = interesting_bf16((256,), sa)
    b = interesting_bf16((256,), sb)
    x, y = ref.two_sum(jnp.asarray(a), jnp.asarray(b))
    lhs = a.astype(np.float64) + b.astype(np.float64)
    rhs = np.asarray(x, np.float64) + np.asarray(y, np.float64)
    np.testing.assert_array_equal(lhs, rhs)


@settings(max_examples=40, deadline=None)
@given(s=st.integers(-6, 6))
def test_fast2sum_error_bound_thm41(s):
    """Thm 4.1: |y| <= ulp(x)/2."""
    a = interesting_bf16((256,), s)
    b = bf16(interesting_bf16((256,), s) * 1e-2)
    big = np.where(np.abs(a) >= np.abs(b), a, b)
    small = np.where(np.abs(a) >= np.abs(b), b, a)
    x, y = ref.fast2sum(jnp.asarray(big), jnp.asarray(small))
    x, y = np.asarray(x), np.asarray(y)
    # ulp(x) for bf16 = 2^(e-7)
    with np.errstate(divide="ignore"):
        e = np.floor(np.log2(np.abs(x), where=x != 0, out=np.zeros_like(x)))
    ulp = np.exp2(e - 7)
    mask = x != 0
    assert np.all(np.abs(y[mask]) <= ulp[mask] / 2 + 1e-45)


def test_two_prod_exact():
    a = interesting_bf16((4096,), 0)
    b = interesting_bf16((4096,), 0)
    x, e = ref.two_prod(jnp.asarray(a), jnp.asarray(b))
    lhs = a.astype(np.float64) * b.astype(np.float64)
    rhs = np.asarray(x, np.float64) + np.asarray(e, np.float64)
    # exclude products that underflow bf16's error representability
    mask = np.abs(lhs) > 1e-30
    np.testing.assert_array_equal(lhs[mask], rhs[mask])


def test_beta2_expansions_table1():
    """Paper Table 1: exact bf16 expansions of β₂."""
    hi, lo = ref.split_scalar(0.999)
    assert hi == 1.0 and abs(lo + 0.001) < 1e-5
    hi, lo = ref.split_scalar(0.95)
    assert hi == 0.94921875
    assert abs((hi + lo) - 0.95) < 1e-6
    # plain bf16 rounds 0.999 to 1.0 — the paper's Sec. 2.2 example
    assert float(jnp.asarray(0.999, jnp.bfloat16)) == 1.0


def test_lost_arithmetic_example():
    """Sec. 3.1: F_bf16(200 ⊕ 0.1) = 200."""
    out = ref.badd(jnp.float32(200.0), jnp.float32(0.1))
    assert float(out) == 200.0


# ---------------------------------------------------------------------------
# Fused optimizer kernels vs oracle (bitwise), across regimes.
# ---------------------------------------------------------------------------


def _scal(beta2=0.999, t=3, lr=1e-3):
    bc1 = 1.0 - 0.9**t
    bc2 = 1.0 - beta2**t
    return ref.pack_scalars(0.9, beta2, bc1, bc2, lr, 1e-8, 0.1)


def _state(n, theta_scale=1.0):
    theta = bf16(RNG.normal(size=n).astype(np.float32) * theta_scale)
    g = bf16(RNG.normal(size=n).astype(np.float32) * 0.01)
    zeros = np.zeros(n, np.float32)
    m = bf16(RNG.normal(size=n).astype(np.float32) * 0.001)
    v = bf16(np.abs(RNG.normal(size=n)).astype(np.float32) * 1e-4)
    return g, theta, zeros.copy(), m, v, zeros.copy()


@pytest.mark.parametrize("beta2", [0.95, 0.99, 0.999])
@pytest.mark.parametrize("theta_scale", [0.02, 1.0, 100.0])
def test_fused_kernels_match_oracle(beta2, theta_scale):
    n = 2 * mcf.BLOCK
    g, theta, dc, m, v, dv = _state(n, theta_scale)
    scal = _scal(beta2)
    sd = ref.unpack_scalars(scal)

    outs = mcf.adamw_a(scal, g, theta, m, v)
    refs = ref.adamw_step_a(jnp.asarray(g), jnp.asarray(theta), jnp.asarray(m), jnp.asarray(v), sd)
    for i, (k, r) in enumerate(zip(outs, refs)):
        assert_bitwise(k, r, f"adamw_a[{i}]")

    outs = mcf.collage_light(scal, g, theta, dc, m, v)
    refs = ref.adamw_step_light(
        jnp.asarray(g), jnp.asarray(theta), jnp.asarray(dc), jnp.asarray(m), jnp.asarray(v), sd
    )
    for i, (k, r) in enumerate(zip(outs, refs)):
        assert_bitwise(k, r, f"light[{i}]")

    outs = mcf.collage_plus(scal, g, theta, dc, m, v, dv)
    refs = ref.adamw_step_plus(
        jnp.asarray(g), jnp.asarray(theta), jnp.asarray(dc), jnp.asarray(m),
        jnp.asarray(v), jnp.asarray(dv), sd,
    )
    for i, (k, r) in enumerate(zip(outs, refs)):
        assert_bitwise(k, r, f"plus[{i}]")

    outs = mcf.kahan(scal, g, theta, dc, m, v)
    refs = ref.adamw_step_kahan(
        jnp.asarray(g), jnp.asarray(theta), jnp.asarray(dc), jnp.asarray(m), jnp.asarray(v), sd
    )
    for i, (k, r) in enumerate(zip(outs, refs)):
        assert_bitwise(k, r, f"kahan[{i}]")


def test_fused_kernel_rejects_unpadded():
    n = mcf.BLOCK + 1
    g = np.zeros(n, np.float32)
    with pytest.raises(ValueError, match="padded"):
        mcf.adamw_a(_scal(), g, g, g, g)


def test_collage_plus_beats_a_on_second_moment_decay():
    """β₂=0.999 (hi component 1.0) makes plain-bf16 v saturate at the point
    where (1-β₂)g² drops below ulp(v)/2 — here v ≈ 2⁻⁸ — while Collage-plus
    keeps tracking the true EMA through δv (paper Sec. 4.2)."""
    import jax

    n = mcf.BLOCK
    scal = _scal(0.999, t=1, lr=0.0)
    theta = bf16(np.ones(n, np.float32))
    zeros = np.zeros(n, np.float32)
    g = bf16(np.full(n, 0.1, np.float32))
    steps = 700

    step_a = jax.jit(mcf.adamw_a)
    step_c = jax.jit(mcf.collage_plus)

    m = zeros.copy()
    v_a = jnp.asarray(zeros)
    for _ in range(steps):
        _, m, v_a, _ = step_a(scal, g, theta, m, v_a)
    m = zeros.copy()
    v_c, dv_c = jnp.asarray(zeros), jnp.asarray(zeros)
    for _ in range(steps):
        _, _, m, v_c, dv_c, _ = step_c(scal, g, theta, zeros, m, v_c, dv_c)

    truth = 0.01 * (1.0 - 0.999**steps)  # true (un-corrected) EMA of g²=0.01
    v_a0 = float(np.asarray(v_a)[0])
    v_c0 = float(np.asarray(v_c)[0] + np.asarray(dv_c)[0])
    # plain bf16: additions of (1-β₂)g² = 1e-5 are lost once v ≥ 2⁻⁸
    assert v_a0 < 0.0045, f"A's v should saturate ≈2^-8, got {v_a0}"
    assert v_c0 > v_a0, f"plus ({v_c0}) must exceed A's saturated v ({v_a0})"
    assert abs(v_c0 - truth) / truth < 0.1, f"plus v {v_c0} vs truth {truth}"
