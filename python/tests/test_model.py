"""L2 model tests: flat-parameter layout, forward shapes, mixed-precision
invariants, gradients."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model as M

CFG = M.CONFIGS["tiny"]


def test_param_table_offsets_are_contiguous():
    rows = M.param_offsets(CFG)
    off = 0
    for name, shape, offset in rows:
        assert offset == off, name
        off += int(np.prod(shape))
    assert off == M.num_params(CFG)


def test_padded_len_is_block_multiple():
    from compile.kernels.mcf import BLOCK

    for cfg in M.CONFIGS.values():
        assert M.padded_len(cfg) % BLOCK == 0
        assert M.padded_len(cfg) >= M.num_params(cfg)


def test_init_params_bf16_representable():
    flat = M.init_params(0, CFG)
    roundtrip = flat.astype(jnp.bfloat16).astype(jnp.float32)
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(roundtrip))


def test_init_deterministic_per_seed():
    a = np.asarray(M.init_params(7, CFG))
    b = np.asarray(M.init_params(7, CFG))
    c = np.asarray(M.init_params(8, CFG))
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


def test_unflatten_shapes_and_padding_unused():
    flat = M.init_params(0, CFG)
    params = M.unflatten(flat, CFG, jnp.bfloat16)
    table = dict((n, s) for n, s in M.param_table(CFG))
    assert set(params) == set(table)
    for name, p in params.items():
        assert p.shape == table[name], name
        assert p.dtype == jnp.bfloat16


def test_forward_shapes_and_dtype():
    flat = M.init_params(0, CFG)
    tok = jnp.zeros((CFG.micro_batch, CFG.seq_len), jnp.int32)
    logits = M.forward(flat, tok, CFG)
    assert logits.shape == (CFG.micro_batch, CFG.seq_len, CFG.vocab)
    assert logits.dtype == jnp.float32


def test_loss_near_uniform_at_init():
    flat = M.init_params(0, CFG)
    rng = np.random.default_rng(0)
    tok = rng.integers(0, CFG.vocab, (CFG.micro_batch, CFG.seq_len)).astype(np.int32)
    tgt = rng.integers(0, CFG.vocab, (CFG.micro_batch, CFG.seq_len)).astype(np.int32)
    loss = float(M.loss_fn(flat, tok, tgt, CFG))
    assert abs(loss - np.log(CFG.vocab)) < 0.5, loss


def test_causality():
    """Changing a future token must not change past logits."""
    flat = M.init_params(0, CFG)
    rng = np.random.default_rng(1)
    tok = rng.integers(0, CFG.vocab, (1, CFG.seq_len)).astype(np.int32)
    l1 = np.asarray(M.forward(flat, jnp.asarray(tok), CFG))
    tok2 = tok.copy()
    tok2[0, -1] = (tok2[0, -1] + 1) % CFG.vocab
    l2 = np.asarray(M.forward(flat, jnp.asarray(tok2), CFG))
    cut = CFG.seq_len - 1
    np.testing.assert_array_equal(l1[0, :cut], l2[0, :cut])
    assert not np.array_equal(l1[0, -1], l2[0, -1])


def test_grad_zero_on_padding():
    flat = M.init_params(0, CFG)
    rng = np.random.default_rng(2)
    tok = rng.integers(0, CFG.vocab, (CFG.micro_batch, CFG.seq_len)).astype(np.int32)
    tgt = rng.integers(0, CFG.vocab, (CFG.micro_batch, CFG.seq_len)).astype(np.int32)
    _, g = M.loss_and_grad(flat, jnp.asarray(tok), jnp.asarray(tgt), CFG)
    g = np.asarray(g)
    n = M.num_params(CFG)
    np.testing.assert_array_equal(g[n:], 0.0)
    assert np.abs(g[:n]).max() > 0.0


def test_grad_direction_decreases_loss():
    flat = M.init_params(0, CFG)
    rng = np.random.default_rng(3)
    tok = rng.integers(0, CFG.vocab, (CFG.micro_batch, CFG.seq_len)).astype(np.int32)
    tgt = rng.integers(0, CFG.vocab, (CFG.micro_batch, CFG.seq_len)).astype(np.int32)
    loss0, g = M.loss_and_grad(flat, jnp.asarray(tok), jnp.asarray(tgt), CFG)
    stepped = flat - 0.5 * g
    loss1 = M.loss_fn(stepped, jnp.asarray(tok), jnp.asarray(tgt), CFG)
    assert float(loss1) < float(loss0)


def test_rope_rotation_properties():
    """RoPE must be position-dependent, norm-preserving, and make the
    q·k inner product depend only on relative position."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(1, 2, 8, 16)).astype(np.float32))
    positions = jnp.arange(8)
    y = np.asarray(M._rope(x, positions))
    # position 0 is the identity rotation
    np.testing.assert_allclose(y[0, 0, 0], np.asarray(x)[0, 0, 0], rtol=1e-5)
    # later positions rotate (different from input)
    assert not np.allclose(y[0, 0, 5], np.asarray(x)[0, 0, 5], atol=1e-4)
    # rotations preserve norms
    np.testing.assert_allclose(
        np.linalg.norm(y, axis=-1), np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-4
    )
    # relative-position property: <rope(q,i), rope(k,j)> == <rope(q,i+d), rope(k,j+d)>
    q = jnp.asarray(rng.normal(size=(1, 1, 1, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 1, 1, 16)).astype(np.float32))
    def dot_at(i, j):
        qi = np.asarray(M._rope(q, jnp.asarray([i])))[0, 0, 0]
        kj = np.asarray(M._rope(k, jnp.asarray([j])))[0, 0, 0]
        return float(qi @ kj)
    np.testing.assert_allclose(dot_at(2, 5), dot_at(4, 7), rtol=1e-4)
    assert abs(dot_at(2, 5) - dot_at(2, 7)) > 1e-5


def test_fp32_compute_dtype_changes_numerics():
    flat = M.init_params(0, CFG)
    rng = np.random.default_rng(4)
    tok = jnp.asarray(rng.integers(0, CFG.vocab, (1, CFG.seq_len)).astype(np.int32))
    lb = np.asarray(M.forward(flat, tok, CFG, jnp.bfloat16))
    lf = np.asarray(M.forward(flat, tok, CFG, jnp.float32))
    assert not np.array_equal(lb, lf)
    # but they agree loosely (bf16 noise only)
    np.testing.assert_allclose(lb, lf, atol=0.2, rtol=0.2)
